//! Crate-wide error type (hand-rolled Display/Error impls — the offline
//! crate set has no thiserror).

use std::fmt;

/// All failure modes of the adaq coordinator.
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Xla(String),
    Format { path: String, msg: String },
    Json { at: usize, msg: String },
    Shape(String),
    Model(String),
    Cli(String),
    Calibration(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(msg) => write!(f, "xla/pjrt error: {msg}"),
            Error::Format { path, msg } => write!(f, "format error in {path}: {msg}"),
            Error::Json { at, msg } => write!(f, "json parse error at byte {at}: {msg}"),
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Model(msg) => write!(f, "model error: {msg}"),
            Error::Cli(msg) => write!(f, "cli error: {msg}"),
            Error::Calibration(msg) => write!(f, "calibration failed: {msg}"),
            Error::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Convenience constructor for format errors.
    pub fn format(path: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Format { path: path.into(), msg: msg.into() }
    }
}

pub type Result<T> = std::result::Result<T, Error>;
