//! Crate-wide error type.

use thiserror::Error;

/// All failure modes of the adaq coordinator.
#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("format error in {path}: {msg}")]
    Format { path: String, msg: String },

    #[error("json parse error at byte {at}: {msg}")]
    Json { at: usize, msg: String },

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("model error: {0}")]
    Model(String),

    #[error("cli error: {0}")]
    Cli(String),

    #[error("calibration failed: {0}")]
    Calibration(String),

    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Convenience constructor for format errors.
    pub fn format(path: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Format { path: path.into(), msg: msg.into() }
    }
}

pub type Result<T> = std::result::Result<T, Error>;
