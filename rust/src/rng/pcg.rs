//! PCG32 (pcg_oneseq_64_xsh_rr_32), mirrored exactly by
//! `python/compile/pcg.py` — the procedural dataset is derived from this
//! stream on both sides, giving bit-identical artifacts (parity-tested in
//! `rust/tests/dataset_parity.rs`).

const MULT: u64 = 6364136223846793005;
const INC: u64 = 1442695040888963407;

/// Single-stream PCG32 with the oneseq increment.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
}

impl Pcg32 {
    /// Seeded construction, matching the reference `pcg32_srandom` flow:
    /// state=0 → advance → add seed → advance.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg32 { state: 0 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next 32 raw bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(INC);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1): u32 / 2^32 computed in f64, rounded once to f32
    /// — identical to the Python side.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() as f64 / 4294967296.0) as f32
    }

    /// Uniform in [0, 1) with full f64 resolution of the 32-bit draw.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 / 4294967296.0
    }

    /// Uniform in [lo, hi) as f32: `lo + (hi-lo) * u` computed in f64 then
    /// rounded once — identical to `Pcg32.uniform` in Python. Bounds are
    /// f64 on purpose: literals like `0.05` must mean the same f64 the
    /// Python side uses, not a pre-rounded f32.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f32 {
        (lo + (hi - lo) * (self.next_u32() as f64 / 4294967296.0)) as f32
    }

    /// Uniform integer in [0, n) via modulo (bias acceptable; identical on
    /// both sides).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        self.next_u32() % n
    }

    /// Exponentially-distributed sample with the given `mean` (inverse
    /// CDF of one 32-bit draw): `-mean · ln(1 − u)`, u ∈ [0, 1). With
    /// `mean = 1/λ` this is the inter-arrival gap of a Poisson process
    /// at rate λ — the open-loop serve harness draws its seeded arrival
    /// schedule from exactly this sequence, so the schedule is bitwise
    /// reproducible per seed.
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // u < 1 always, so 1-u ∈ (0, 1] and ln never sees 0
        -mean * (1.0 - self.next_f64()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vector generated from the Python implementation
    /// (`python/compile/pcg.py`, seed 42) — guards cross-language parity.
    #[test]
    fn matches_python_stream_seed42() {
        let mut rng = Pcg32::new(42);
        let got: Vec<u32> = (0..8).map(|_| rng.next_u32()).collect();
        // regenerate with: python -c "from compile.pcg import Pcg32;
        //   r=Pcg32(42); print([r.next_u32() for _ in range(8)])"
        let expect = [3270867926u32, 1795671209, 1924641435, 1143034755, 4121910957, 1757328946, 3418829100, 3589261271];
        assert_eq!(got, expect, "PCG32 stream diverged from the reference");
    }

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u32> = { let mut r = Pcg32::new(1); (0..16).map(|_| r.next_u32()).collect() };
        let b: Vec<u32> = { let mut r = Pcg32::new(1); (0..16).map(|_| r.next_u32()).collect() };
        let c: Vec<u32> = { let mut r = Pcg32::new(2); (0..16).map(|_| r.next_u32()).collect() };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn exponential_moments_and_support() {
        let mut rng = Pcg32::new(13);
        let mean = 4.0;
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let v = rng.exponential(mean);
            assert!(v >= 0.0 && v.is_finite(), "exponential sample {v} out of support");
            sum += v;
            sumsq += v * v;
        }
        let m = sum / n as f64;
        let var = sumsq / n as f64 - m * m;
        // Exp(mean): E = mean, Var = mean² (loose 5% tolerance)
        assert!((m - mean).abs() < 0.05 * mean, "mean {m}");
        assert!((var - mean * mean).abs() < 0.10 * mean * mean, "var {var}");
        // deterministic per seed
        let a = Pcg32::new(99).exponential(1.0);
        let b = Pcg32::new(99).exponential(1.0);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Pcg32::new(5);
        for _ in 0..10_000 {
            let v = rng.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }
}
