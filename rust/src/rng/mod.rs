//! Deterministic RNG: PCG32 (bit-compatible with `python/compile/pcg.py`)
//! plus helpers for the noise-injection experiments.

mod pcg;

pub use pcg::Pcg32;

/// Fill a slice with U(-0.5, 0.5) samples — the noise shape used by the
/// paper's Algorithm 1 (t_i calibration).
pub fn fill_uniform_pm_half(rng: &mut Pcg32, out: &mut [f32]) {
    for v in out.iter_mut() {
        *v = rng.uniform(-0.5, 0.5);
    }
}

/// Standard-normal samples via Box-Muller (used only by Rust-side tests
/// and synthetic benches, never by the parity-checked dataset path).
pub fn fill_normal(rng: &mut Pcg32, out: &mut [f32]) {
    let mut i = 0;
    while i < out.len() {
        let u1 = (rng.next_f64()).max(1e-12);
        let u2 = rng.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        out[i] = (r * th.cos()) as f32;
        if i + 1 < out.len() {
            out[i + 1] = (r * th.sin()) as f32;
        }
        i += 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_pm_half_in_range() {
        let mut rng = Pcg32::new(7);
        let mut buf = vec![0f32; 10_000];
        fill_uniform_pm_half(&mut rng, &mut buf);
        assert!(buf.iter().all(|&v| (-0.5..0.5).contains(&v)));
        let mean: f64 = buf.iter().map(|&v| v as f64).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        // var of U(-0.5,0.5) is 1/12
        let var: f64 =
            buf.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(9);
        let mut buf = vec![0f32; 20_000];
        fill_normal(&mut rng, &mut buf);
        let mean: f64 = buf.iter().map(|&v| v as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
