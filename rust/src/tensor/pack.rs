//! Panel packing for the GEMM microkernels.
//!
//! Both GEMMs share one B layout: NR-wide column panels, k-major inside a
//! panel (`packed[jp][p][0..NR] = b[p][jp*NR .. jp*NR+NR]`), zero-padded
//! on the right edge. Every kernel (scalar, AVX2, NEON) consumes this
//! format, so a [`PackedI8`] built once per quantized layer serves
//! whatever kernel the dispatch picks at runtime.
//!
//! A is packed too, but per row-panel inside the SIMD kernels rather than
//! up front: one MR×k panel (`apack[p*mr + r]`) is a few KiB, stays L1-hot
//! while it is consumed, and lets the microkernel broadcast all MR values
//! of a k-step from one cache line instead of MR strided `a[(i+r)*k + p]`
//! loads. Edge panels are zero-row padded so kernels always compute a full
//! MR tile and only write back the real rows.

/// Microkernel column tile (one packed B panel).
pub(crate) const NR: usize = 8;
/// k-dimension block for the f32 kernels: one A panel slab of KC stays in
/// L1 while a packed B panel streams through.
pub(crate) const KC: usize = 256;

/// Length of the packed-B buffer for a k×n matrix.
pub(crate) fn packed_b_len(k: usize, n: usize) -> usize {
    n.div_ceil(NR) * k * NR
}

/// Pack B (k×n row-major) into NR-wide column panels. The buffer is
/// caller-provided and may hold stale data: interior panels are copy-only,
/// and only the right-edge panel's `NR - w` padding lanes are zeroed —
/// no full-buffer re-zero per call.
pub(crate) fn pack_b(b: &[f32], k: usize, n: usize, packed: &mut Vec<f32>) {
    let npanels = n.div_ceil(NR);
    let need = npanels * k * NR;
    if packed.len() < need {
        packed.resize(need, 0.0);
    } else {
        packed.truncate(need);
    }
    for jp in 0..npanels {
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let base = jp * k * NR;
        for p in 0..k {
            let src = p * n + j0;
            let dst = base + p * NR;
            packed[dst..dst + w].copy_from_slice(&b[src..src + w]);
            // stale contents from a recycled buffer must not leak into
            // the padding lanes of the edge panel
            packed[dst + w..dst + NR].fill(0.0);
        }
    }
}

/// Pack `rows` rows of A (m×k row-major) starting at row `i0` into one
/// k-major register panel: `apack[p*mr + r] = a[(i0+r)*k + p]`, rows
/// `rows..mr` zero-filled. Every slot is written, so the buffer may hold
/// stale data.
pub(crate) fn pack_a_panel(
    a: &[f32],
    i0: usize,
    rows: usize,
    k: usize,
    mr: usize,
    apack: &mut Vec<f32>,
) {
    debug_assert!(rows >= 1 && rows <= mr);
    let need = k * mr;
    if apack.len() < need {
        apack.resize(need, 0.0);
    } else {
        apack.truncate(need);
    }
    for r in 0..rows {
        let row = &a[(i0 + r) * k..(i0 + r + 1) * k];
        for (p, &v) in row.iter().enumerate() {
            apack[p * mr + r] = v;
        }
    }
    for r in rows..mr {
        for p in 0..k {
            apack[p * mr + r] = 0.0;
        }
    }
}

/// Pack `rows` rows of int8 A starting at `i0` into a pair-interleaved
/// k-major panel: `apack[p2*mr*2 + r*2 + d] = a[(i0+r)*k + 2*p2 + d]`,
/// zero-padded past k (odd k) and past `rows`. Pads to the same even-k
/// boundary as [`PackedI8`], so the widening-multiply kernels consume
/// whole (a, b) k-pairs with no tail case; the pad terms multiply by zero
/// and keep the result bit-exact.
pub(crate) fn pack_a_i8_panel(
    a: &[i8],
    i0: usize,
    rows: usize,
    k: usize,
    mr: usize,
    apack: &mut Vec<i8>,
) {
    debug_assert!(rows >= 1 && rows <= mr);
    let kp = k.div_ceil(2);
    let need = kp * mr * 2;
    if apack.len() < need {
        apack.resize(need, 0);
    } else {
        apack.truncate(need);
    }
    for r in 0..rows {
        let row = &a[(i0 + r) * k..(i0 + r + 1) * k];
        for p2 in 0..k / 2 {
            apack[p2 * mr * 2 + r * 2] = row[2 * p2];
            apack[p2 * mr * 2 + r * 2 + 1] = row[2 * p2 + 1];
        }
        if k % 2 == 1 {
            apack[(kp - 1) * mr * 2 + r * 2] = row[k - 1];
            apack[(kp - 1) * mr * 2 + r * 2 + 1] = 0;
        }
    }
    for r in rows..mr {
        for p2 in 0..kp {
            apack[p2 * mr * 2 + r * 2] = 0;
            apack[p2 * mr * 2 + r * 2 + 1] = 0;
        }
    }
}

/// B matrix packed into NR-wide int8 column panels, ready for
/// [`crate::tensor::gemm_i8_packed`]. Quantized layers build this once per
/// bit-vector and reuse it across serve requests — the layout is
/// kernel-independent, so a cached pack works under whatever kernel the
/// runtime dispatch selects.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedI8 {
    pub(crate) panels: Vec<i8>,
    pub(crate) k: usize,
    /// Panel row stride: k rounded up to even, rows `k..kstride` zero.
    /// Lets the SIMD kernels read whole 2×NR k-pair blocks without a
    /// bounds-straddling tail load on odd k.
    pub(crate) kstride: usize,
    pub(crate) n: usize,
}

impl PackedI8 {
    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

/// Pack an int8 B (k×n row-major) into NR-wide column panels, zero-padded
/// on the right edge and to an even number of k rows — the i8 twin of the
/// f32 `pack_b`.
pub fn pack_i8(b: &[i8], k: usize, n: usize) -> PackedI8 {
    assert_eq!(b.len(), k * n, "rhs size");
    let npanels = n.div_ceil(NR);
    let kstride = k + (k & 1);
    let mut panels = vec![0i8; npanels * kstride * NR];
    for jp in 0..npanels {
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let base = jp * kstride * NR;
        for p in 0..k {
            let src = p * n + j0;
            panels[base + p * NR..base + p * NR + w].copy_from_slice(&b[src..src + w]);
        }
    }
    PackedI8 { panels, k, kstride, n }
}
