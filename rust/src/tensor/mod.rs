//! Minimal dense tensors: `Tensor` (f32) and `IntTensor` (i32).
//!
//! Just enough linear algebra for the coordinator: the heavy compute runs
//! through PJRT (L1/L2 artifacts) or the [`crate::nn`] substrate; this
//! module provides shapes, storage, reductions and the GEMM that `nn`
//! builds its conv on.

use crate::{Error, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from parts; checks that the element count matches the shape.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row `r` of a rank-2 tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    /// Minimum element (NaN-poisoning ignored; tensors here are finite).
    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    /// Maximum element.
    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Σ x² in f64 (the measurement accumulators need the headroom).
    pub fn l2_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Elementwise a − b.
    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "sub: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    /// Elementwise a + b.
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "add: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    /// Elementwise scale.
    pub fn scale(&self, k: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| v * k).collect(),
        }
    }

    /// Indices of the two largest entries of a 1-D slice, returned as
    /// (argmax, arg-second-max). Used for the adversarial margin
    /// (z₍₁₎ − z₍₂₎)²/2 of Eq. 13 and for accuracy.
    pub fn top2(row: &[f32]) -> (usize, usize) {
        debug_assert!(row.len() >= 2);
        let (mut i1, mut i2) = if row[0] >= row[1] { (0, 1) } else { (1, 0) };
        for (i, &v) in row.iter().enumerate().skip(2) {
            if v > row[i1] {
                i2 = i1;
                i1 = i;
            } else if v > row[i2] {
                i2 = i;
            }
        }
        (i1, i2)
    }
}

/// Dense row-major i32 tensor (labels).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }
}

/// C = A(m×k) · B(k×n), accumulating in f32 with a blocked inner loop.
/// This is the pure-Rust GEMM under `nn::conv2d` (im2col) and `nn::dense`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.ndim() != 2 || b.ndim() != 2 {
        return Err(Error::Shape("matmul wants rank-2 operands".into()));
    }
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    if k != k2 {
        return Err(Error::Shape(format!("matmul: {m}x{k} vs {k2}x{n}")));
    }
    let mut out = vec![0f32; m * n];
    // ikj loop order: streams B rows, keeps C row hot.
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are sparse
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        let t = t.reshape(&[3, 2]).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert!(t.clone().reshape(&[7]).is_err());
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let eye = Tensor::from_vec(&[3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]).unwrap();
        assert_eq!(matmul(&a, &eye).unwrap().data(), a.data());
    }

    #[test]
    fn top2_orderings() {
        assert_eq!(Tensor::top2(&[3.0, 1.0, 2.0]), (0, 2));
        assert_eq!(Tensor::top2(&[1.0, 3.0, 2.0]), (1, 2));
        assert_eq!(Tensor::top2(&[1.0, 2.0, 3.0]), (2, 1));
        assert_eq!(Tensor::top2(&[5.0, 5.0]), (0, 1));
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(&[4], vec![-2.0, 0.0, 1.0, 3.0]).unwrap();
        assert_eq!(t.min(), -2.0);
        assert_eq!(t.max(), 3.0);
        assert_eq!(t.l2_sq(), 14.0);
    }

    #[test]
    fn sub_shape_check() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[4]);
        assert!(a.sub(&b).is_err());
    }
}
