//! Minimal dense tensors: `Tensor` (f32) and `IntTensor` (i32).
//!
//! Just enough linear algebra for the coordinator: the heavy compute runs
//! through PJRT (L1/L2 artifacts) or the [`crate::nn`] substrate; this
//! module provides shapes, storage, reductions and the GEMM that `nn`
//! builds its conv on.
//!
//! The GEMM itself is a small module family (ARCHITECTURE.md §Compute
//! kernels):
//!
//! * [`pack`](self) — shared packed-panel formats (NR-wide B panels,
//!   per-row-panel A packs, [`PackedI8`]);
//! * `kernel::{scalar, avx2, neon}` — MR×NR microkernels per instruction
//!   set, scalar being the portable fallback and correctness reference;
//! * `dispatch` — picks the best kernel once per process
//!   ([`active_kernel`], forced portable via `ADAQ_FORCE_SCALAR=1`);
//! * this file — the public API: drivers that pack, split rows across
//!   `std::thread::scope` threads, and call the dispatched kernel.

use crate::{Error, Result};

mod dispatch;
mod kernel;
mod pack;

pub use dispatch::{active_kernel, kernel_names};
pub use pack::{pack_i8, PackedI8};

use dispatch::GemmKernel;
use pack::{pack_b, packed_b_len};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from parts; checks that the element count matches the shape.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row `r` of a rank-2 tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    /// Minimum element (NaN-poisoning ignored; tensors here are finite).
    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    /// Maximum element.
    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Σ x² in f64 (the measurement accumulators need the headroom).
    pub fn l2_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Elementwise a − b.
    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "sub: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    /// Elementwise a + b.
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "add: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    /// Elementwise scale.
    pub fn scale(&self, k: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| v * k).collect(),
        }
    }

    /// Transpose of a rank-2 tensor.
    pub fn transpose2(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            return Err(Error::Shape(format!("transpose2 wants rank-2, got {:?}", self.shape)));
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    /// Overwrite `self` with `a + k·b` elementwise — the calibration
    /// probe's noise-injection step, allocation-free across probes.
    pub fn assign_add_scaled(&mut self, a: &Tensor, b: &Tensor, k: f32) -> Result<()> {
        if self.shape != a.shape || self.shape != b.shape {
            return Err(Error::Shape(format!(
                "assign_add_scaled: {:?} vs {:?} vs {:?}",
                self.shape, a.shape, b.shape
            )));
        }
        for ((o, &av), &bv) in self.data.iter_mut().zip(&a.data).zip(&b.data) {
            *o = av + k * bv;
        }
        Ok(())
    }

    /// Indices of the two largest entries of a 1-D slice, returned as
    /// (argmax, arg-second-max). Used for the adversarial margin
    /// (z₍₁₎ − z₍₂₎)²/2 of Eq. 13 and for accuracy.
    pub fn top2(row: &[f32]) -> (usize, usize) {
        debug_assert!(row.len() >= 2);
        let (mut i1, mut i2) = if row[0] >= row[1] { (0, 1) } else { (1, 0) };
        for (i, &v) in row.iter().enumerate().skip(2) {
            if v > row[i1] {
                i2 = i1;
                i1 = i;
            } else if v > row[i2] {
                i2 = i;
            }
        }
        (i1, i2)
    }
}

/// Dense row-major i32 tensor (labels).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }
}

// ---------------------------------------------------------------------------
// GEMM — the compute core under `nn::conv2d` (im2col) and `nn::dense`.
//
// [`matmul`] is a cache-blocked, register-tiled implementation: B is packed
// into NR-wide column panels once, the runtime-dispatched microkernel
// (scalar / AVX2+FMA / NEON — see [`active_kernel`]) keeps an MR×NR
// accumulator block in registers, and row blocks are distributed across
// `std::thread::scope` threads. Per output element the k-summation order is
// fixed (ascending p within KC blocks, blocks in ascending order) and does
// not depend on the thread count, batch split or row position, so threaded
// and single-threaded runs agree **bitwise** within a kernel — the
// cross-backend and serve determinism tests rely on that. Numbers differ
// *between* kernels (FMA contraction); the int8 GEMM below is bit-exact
// across all kernels.
//
// [`matmul_sparse_lhs`] keeps the seed's `if av == 0.0 { continue; }`
// skip for genuinely sparse left operands (post-ReLU activations); the
// branch was removed from the dense kernel because on dense weights it
// defeats branch prediction and blocks vectorization of the inner loop.
// ---------------------------------------------------------------------------

use std::cell::Cell;

thread_local! {
    /// Per-thread override for the GEMM thread count; 0 = auto. Worker
    /// threads that already own one slice of a batch-parallel evaluation
    /// set this to 1 so nested GEMMs don't oversubscribe the machine.
    static GEMM_THREADS: Cell<usize> = Cell::new(0);
    /// Per-thread *ceiling* on auto-picked GEMM threads; 0 = no cap.
    /// Unlike [`set_gemm_threads`] (a hard override that also forces
    /// threading onto products too small to amortize spawns), the cap
    /// only limits what auto-threading may choose — tiny GEMMs still run
    /// inline. Serve workers use this to split the machine: W workers ×
    /// cap(threads/W) GEMM threads never oversubscribe.
    static GEMM_THREAD_CAP: Cell<usize> = Cell::new(0);
    /// Per-thread B-panel pack buffer, reused across GEMM calls so the
    /// steady-state hot path (same weight shapes every batch/probe) does
    /// not allocate per multiply.
    static PACK_BUF: Cell<Vec<f32>> = Cell::new(Vec::new());
    /// Per-thread A-panel buffer for the f32 SIMD kernels (one MR×k
    /// panel), same recycling story as `PACK_BUF`.
    static APACK_BUF: Cell<Vec<f32>> = Cell::new(Vec::new());
    /// Per-thread A-panel buffer for the int8 SIMD kernels.
    static APACK_I8_BUF: Cell<Vec<i8>> = Cell::new(Vec::new());
}

/// Force the GEMM thread count on the *calling thread* (0 restores auto).
pub fn set_gemm_threads(n: usize) {
    GEMM_THREADS.with(|c| c.set(n));
}

/// The calling thread's GEMM thread-count override (0 = auto). Lets
/// callers that need to pin temporarily (e.g. a backend running inline
/// under an outer job pool) save and restore the previous setting.
pub fn gemm_threads() -> usize {
    GEMM_THREADS.with(|c| c.get())
}

/// Cap auto-picked GEMM threads on the *calling thread* (0 removes the
/// cap). Small products still run inline; big ones use at most `n`
/// threads. A [`set_gemm_threads`] override takes precedence.
pub fn set_gemm_thread_cap(n: usize) {
    GEMM_THREAD_CAP.with(|c| c.set(n));
}

/// The calling thread's auto-threading cap (0 = uncapped).
pub fn gemm_thread_cap() -> usize {
    GEMM_THREAD_CAP.with(|c| c.get())
}

/// Process-wide ceiling on auto-picked GEMM threads from
/// `ADAQ_GEMM_MAX_THREADS` (read once; unset, 0 or unparsable =
/// uncapped). Replaces the old hardcoded `.min(16)`: big machines use
/// every core by default, and deployments that want the old behavior set
/// the variable. The per-thread [`set_gemm_thread_cap`] composes on top.
fn gemm_max_threads() -> usize {
    static CAP: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("ADAQ_GEMM_MAX_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&v| v > 0)
            .unwrap_or(usize::MAX)
    })
}

/// Threads to use for an m×k·k×n product: the thread-local override if
/// set, else all cores (bounded by `ADAQ_GEMM_MAX_THREADS` and the
/// thread-local cap) for products big enough to amortize the spawns.
fn gemm_auto_threads(m: usize, n: usize, k: usize) -> usize {
    let forced = GEMM_THREADS.with(|c| c.get());
    if forced != 0 {
        return forced;
    }
    let flops = m.saturating_mul(n).saturating_mul(k);
    if flops < (1 << 22) || m < 2 * kernel::scalar::MR_F32 {
        return 1;
    }
    let auto = std::thread::available_parallelism()
        .map_or(1, |v| v.get())
        .min(gemm_max_threads());
    match GEMM_THREAD_CAP.with(|c| c.get()) {
        0 => auto,
        cap => auto.min(cap),
    }
}

/// Shared f32 driver: pack B, then run the kernel inline or across
/// MR-aligned row chunks. The split never changes the per-element
/// accumulation order, only who computes which rows.
fn matmul_into_kern(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
    kern: &'static GemmKernel,
    packed: &mut Vec<f32>,
    apack: &mut Vec<f32>,
) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(b.len(), k * n, "rhs size");
    assert_eq!(out.len(), m * n, "out size");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let threads = if threads == 0 { gemm_auto_threads(m, n, k) } else { threads };
    pack_b(b, k, n, packed);
    let mr = kern.mr_f32;
    if threads <= 1 || m < 2 * mr {
        (kern.f32_rows)(a, packed, out, 0, m, k, n, apack);
    } else {
        let rows_per = m.div_ceil(threads).div_ceil(mr) * mr;
        let packed: &[f32] = packed;
        std::thread::scope(|s| {
            for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let r0 = ci * rows_per;
                let r1 = (r0 + rows_per).min(m);
                s.spawn(move || {
                    // fresh per-spawn A-pack buffer: scoped threads are
                    // short-lived, one MR×k grow per spawn is noise next
                    // to the row chunk it packs
                    let mut apack = Vec::new();
                    (kern.f32_rows)(a, packed, chunk, r0, r1, k, n, &mut apack);
                });
            }
        });
    }
}

/// Blocked GEMM into a caller-provided (zeroed) output slice:
/// `out[m×n] += a[m×k] · b[k×n]`. `threads == 0` picks automatically.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    matmul_into_threaded(a, b, m, k, n, out, 0)
}

/// [`matmul_into`] with an explicit thread count (0 = auto).
pub fn matmul_into_threaded(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
) {
    // take the per-thread pack buffers out, pack into them, put them
    // back — steady-state GEMMs (same shapes every batch) allocate nothing
    let mut packed = PACK_BUF.with(|c| c.take());
    let mut apack = APACK_BUF.with(|c| c.take());
    matmul_into_kern(a, b, m, k, n, out, threads, dispatch::active(), &mut packed, &mut apack);
    PACK_BUF.with(|c| c.set(packed));
    APACK_BUF.with(|c| c.set(apack));
}

/// [`matmul_into`] drawing its pack buffers from a [`crate::util::Scratch`]
/// arena instead of the thread-locals — the `nn` fused ops route their
/// per-evaluation scratch through this so the A/B panel buffers live in
/// the same recycled pool as the im2col patches and activations.
pub fn matmul_into_scratch(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    scratch: &mut crate::util::Scratch,
) {
    let kern = dispatch::active();
    // both buffers are fully written before use (pack_b zeroes only edge
    // padding; pack_a_panel writes every slot), so stale contents are fine
    let mut packed = scratch.take_any(packed_b_len(k, n));
    let mut apack = scratch.take_any(kern.mr_f32 * k);
    matmul_into_kern(a, b, m, k, n, out, 0, kern, &mut packed, &mut apack);
    scratch.put(packed);
    scratch.put(apack);
}

/// [`matmul_into`] pinned to a named kernel from [`kernel_names`] — the
/// per-kernel test/bench surface. Unlike a process-global override this
/// cannot race across in-process test threads. Errors on a kernel this
/// host cannot run.
pub fn matmul_into_with_kernel(
    kernel: &str,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
) -> Result<()> {
    let kern = dispatch::by_name(kernel)
        .ok_or_else(|| Error::Other(format!("unknown or unavailable GEMM kernel {kernel:?}")))?;
    let mut packed = Vec::new();
    let mut apack = Vec::new();
    matmul_into_kern(a, b, m, k, n, out, threads, kern, &mut packed, &mut apack);
    Ok(())
}

/// C = A(m×k) · B(k×n): cache-blocked, register-tiled, multithreaded.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_threaded(a, b, 0)
}

/// [`matmul`] with an explicit thread count (0 = auto, 1 = single-thread).
/// Any thread count produces bitwise-identical results.
pub fn matmul_threaded(a: &Tensor, b: &Tensor, threads: usize) -> Result<Tensor> {
    let (m, k, n) = matmul_dims(a, b)?;
    let mut out = vec![0f32; m * n];
    matmul_into_threaded(&a.data, &b.data, m, k, n, &mut out, threads);
    Tensor::from_vec(&[m, n], out)
}

/// The seed's single-threaded ikj loop (no sparsity skip) — kept as the
/// correctness reference and the bench baseline for the blocked kernel.
pub fn matmul_reference(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k, n) = matmul_dims(a, b)?;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// ikj GEMM that skips zero LHS entries — only worth it when the left
/// operand is genuinely sparse (post-ReLU activations); on dense weights
/// the branch costs more than the skipped multiplies (see perf_hotpath).
pub fn matmul_sparse_lhs(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k, n) = matmul_dims(a, b)?;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

// ---------------------------------------------------------------------------
// int8 GEMM — the integer serving kernel under `nn::dense_int8_fused` /
// `nn::conv2d_int8_fused`.
//
// Same structure as the f32 GEMM above: B is packed once into NR-wide
// column panels ([`pack_i8`] → [`PackedI8`], cached per quantized layer so
// the serve path never re-packs weights), and an MR×NR block of **i32**
// accumulators is kept in registers. Unlike the f32 kernel there is no KC
// split: the accumulator block holds the full k-sum for one panel and is
// *stored* (not accumulated) on write-back, so the output buffer does not
// need to be zeroed. Integer accumulation is exact, so results are
// bitwise identical for every thread count, association order — and every
// kernel: the SIMD paths regroup the sum in pairs, which integer
// associativity makes bit-exact against the scalar kernel.
//
// Overflow headroom: |Σ a·b| ≤ 128·128·k (worst case (−128)·(−128)),
// which fits i32 for k ≤ [`I8_GEMM_MAX_K`] — far above any reduction
// dimension in this repo (checked at runtime in [`gemm_i8_packed`]).
// ---------------------------------------------------------------------------

/// Largest reduction dimension the int8 GEMM accepts: |Σ a·b| ≤ 128·128·k
/// must fit in the i32 accumulators, so k ≤ i32::MAX / 16384 = 131 071.
pub const I8_GEMM_MAX_K: usize = 131_071;

/// Shared int8 driver: run the kernel inline or across MR-aligned row
/// chunks. Exact integer math — identical output for any split.
fn gemm_i8_kern(
    a: &[i8],
    b: &PackedI8,
    m: usize,
    out: &mut [i32],
    threads: usize,
    kern: &'static GemmKernel,
    apack: &mut Vec<i8>,
) {
    let (k, n) = (b.k(), b.n());
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(out.len(), m * n, "out size");
    assert!(
        k <= I8_GEMM_MAX_K,
        "int8 GEMM k={k} exceeds the i32 overflow bound k <= {I8_GEMM_MAX_K} \
         (|sum a*b| <= 128*128*k must fit in i32)"
    );
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0);
        return;
    }
    let threads = if threads == 0 { gemm_auto_threads(m, n, k) } else { threads };
    let mr = kern.mr_i8;
    if threads <= 1 || m < 2 * mr {
        (kern.i8_rows)(a, b, out, 0, m, apack);
        return;
    }
    let rows_per = m.div_ceil(threads).div_ceil(mr) * mr;
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            let r0 = ci * rows_per;
            let r1 = (r0 + rows_per).min(m);
            s.spawn(move || {
                let mut apack = Vec::new();
                (kern.i8_rows)(a, b, chunk, r0, r1, &mut apack);
            });
        }
    });
}

/// `out[m×n] = a[m×k] · b_packed[k×n]` in int8×int8→i32. `out` is fully
/// overwritten (stale contents are fine). `threads == 0` picks
/// automatically, honoring [`set_gemm_threads`] like the f32 kernel.
///
/// Panics if `b.k()` exceeds [`I8_GEMM_MAX_K`] (i32 accumulator overflow
/// would silently corrupt results — checked in release builds too).
pub fn gemm_i8_packed(a: &[i8], b: &PackedI8, m: usize, out: &mut [i32], threads: usize) {
    let mut apack = APACK_I8_BUF.with(|c| c.take());
    gemm_i8_kern(a, b, m, out, threads, dispatch::active(), &mut apack);
    APACK_I8_BUF.with(|c| c.set(apack));
}

/// [`gemm_i8_packed`] drawing the A-panel buffer from a
/// [`crate::util::Scratch`] arena — the int8 serve path routes its
/// per-request scratch through this.
pub fn gemm_i8_packed_scratch(
    a: &[i8],
    b: &PackedI8,
    m: usize,
    out: &mut [i32],
    scratch: &mut crate::util::Scratch,
) {
    let kern = dispatch::active();
    let mut apack = scratch.take_i8(kern.mr_i8 * (b.k() + 1));
    gemm_i8_kern(a, b, m, out, 0, kern, &mut apack);
    scratch.put_i8(apack);
}

/// [`gemm_i8_packed`] pinned to a named kernel from [`kernel_names`] —
/// the per-kernel test/bench surface (bit-exactness battery).
pub fn gemm_i8_packed_with_kernel(
    kernel: &str,
    a: &[i8],
    b: &PackedI8,
    m: usize,
    out: &mut [i32],
    threads: usize,
) -> Result<()> {
    let kern = dispatch::by_name(kernel)
        .ok_or_else(|| Error::Other(format!("unknown or unavailable GEMM kernel {kernel:?}")))?;
    let mut apack = Vec::new();
    gemm_i8_kern(a, b, m, out, threads, kern, &mut apack);
    Ok(())
}

/// Convenience int8 GEMM that packs B per call — benches and tests; the
/// serve path packs once via [`pack_i8`] and calls [`gemm_i8_packed`].
pub fn matmul_i8_into(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    let packed = pack_i8(b, k, n);
    gemm_i8_packed(a, &packed, m, out, 0);
}

/// Naive ikj int8 GEMM — correctness reference for the blocked kernel.
pub fn matmul_i8_reference(a: &[i8], b: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    assert_eq!(a.len(), m * k, "lhs size");
    assert_eq!(b.len(), k * n, "rhs size");
    assert_eq!(out.len(), m * n, "out size");
    out.fill(0);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p] as i32;
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut out[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv as i32;
            }
        }
    }
}

fn matmul_dims(a: &Tensor, b: &Tensor) -> Result<(usize, usize, usize)> {
    if a.ndim() != 2 || b.ndim() != 2 {
        return Err(Error::Shape("matmul wants rank-2 operands".into()));
    }
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    if k != k2 {
        return Err(Error::Shape(format!("matmul: {m}x{k} vs {k2}x{n}")));
    }
    Ok((m, k, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|v| v as f32).collect()).unwrap();
        let t = t.reshape(&[3, 2]).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert!(t.clone().reshape(&[7]).is_err());
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let eye = Tensor::from_vec(&[3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]).unwrap();
        assert_eq!(matmul(&a, &eye).unwrap().data(), a.data());
    }

    #[test]
    fn blocked_matches_reference_on_ragged_shape() {
        // 5×7 · 7×9 — nothing divides the microkernel tiles
        let a = Tensor::from_vec(&[5, 7], (0..35).map(|v| (v as f32) * 0.37 - 6.0).collect())
            .unwrap();
        let b = Tensor::from_vec(&[7, 9], (0..63).map(|v| (v as f32) * 0.11 - 3.0).collect())
            .unwrap();
        let blocked = matmul(&a, &b).unwrap();
        let reference = matmul_reference(&a, &b).unwrap();
        for (x, y) in blocked.data().iter().zip(reference.data()) {
            assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn threaded_matches_single_bitwise() {
        let a = Tensor::from_vec(&[33, 21], (0..693).map(|v| (v as f32).sin()).collect()).unwrap();
        let b = Tensor::from_vec(&[21, 17], (0..357).map(|v| (v as f32).cos()).collect()).unwrap();
        let one = matmul_threaded(&a, &b, 1).unwrap();
        let four = matmul_threaded(&a, &b, 4).unwrap();
        for (x, y) in one.data().iter().zip(four.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn dispatch_reports_a_known_kernel() {
        let names = kernel_names();
        assert_eq!(names[0], "scalar", "scalar is always available and listed first");
        let active = active_kernel();
        assert!(names.contains(&active), "active kernel {active} not in {names:?}");
        // the with_kernel surface accepts every listed kernel and rejects
        // unknown names
        let a = Tensor::from_vec(&[3, 5], (0..15).map(|v| v as f32).collect()).unwrap();
        let b = Tensor::from_vec(&[5, 4], (0..20).map(|v| v as f32).collect()).unwrap();
        let reference = matmul_reference(&a, &b).unwrap();
        for name in &names {
            let mut out = vec![0f32; 12];
            matmul_into_with_kernel(name, a.data(), b.data(), 3, 5, 4, &mut out, 1).unwrap();
            for (x, y) in out.iter().zip(reference.data()) {
                assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "{name}: {x} vs {y}");
            }
        }
        let mut out = vec![0f32; 12];
        let bad = matmul_into_with_kernel("avx512", a.data(), b.data(), 3, 5, 4, &mut out, 1);
        assert!(bad.is_err());
    }

    #[test]
    fn pack_buffer_reuse_keeps_edge_panels_clean() {
        // pack_b no longer re-zeroes the whole buffer: a wide product
        // followed by a narrower ragged one on the same thread reuses the
        // pack buffer — stale panel data must not leak into the edge pad
        let mut rng_vals = (0..).map(|v| ((v * 37) % 19) as f32 - 9.0);
        let wide_a: Vec<f32> = (&mut rng_vals).take(4 * 40).collect();
        let wide_b: Vec<f32> = (&mut rng_vals).take(40 * 40).collect();
        let mut wide_out = vec![0f32; 4 * 40];
        matmul_into(&wide_a, &wide_b, 4, 40, 40, &mut wide_out);
        let a = Tensor::from_vec(&[5, 7], (&mut rng_vals).take(35).collect()).unwrap();
        let b = Tensor::from_vec(&[7, 9], (&mut rng_vals).take(63).collect()).unwrap();
        let narrow = matmul(&a, &b).unwrap();
        let reference = matmul_reference(&a, &b).unwrap();
        for (x, y) in narrow.data().iter().zip(reference.data()) {
            assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_scratch_matches_thread_local_path() {
        let mut scratch = crate::util::Scratch::new();
        let a: Vec<f32> = (0..9 * 11).map(|v| (v as f32).sin()).collect();
        let b: Vec<f32> = (0..11 * 6).map(|v| (v as f32).cos()).collect();
        let mut plain = vec![0f32; 9 * 6];
        matmul_into(&a, &b, 9, 11, 6, &mut plain);
        // twice through the same scratch: second call reuses pooled bufs
        for _ in 0..2 {
            let mut out = vec![0f32; 9 * 6];
            matmul_into_scratch(&a, &b, 9, 11, 6, &mut out, &mut scratch);
            for (x, y) in out.iter().zip(&plain) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn sparse_lhs_matches_reference() {
        let mut av: Vec<f32> = (0..60).map(|v| (v as f32) * 0.3 - 9.0).collect();
        for (i, v) in av.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = 0.0;
            }
        }
        let a = Tensor::from_vec(&[6, 10], av).unwrap();
        let b = Tensor::from_vec(&[10, 4], (0..40).map(|v| (v as f32) * 0.21).collect()).unwrap();
        let s = matmul_sparse_lhs(&a, &b).unwrap();
        let r = matmul_reference(&a, &b).unwrap();
        for (x, y) in s.data().iter().zip(r.data()) {
            assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()));
        }
    }

    fn randi8(n: usize, seed: u64) -> Vec<i8> {
        // simple LCG over the full i8 range, deterministic
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 56) as i8
            })
            .collect()
    }

    #[test]
    fn int8_known_small() {
        let a: Vec<i8> = vec![1, 2, 3, 4];
        let b: Vec<i8> = vec![1, 1, 1, 1];
        let mut out = vec![0i32; 4];
        matmul_i8_into(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, &[3, 3, 7, 7]);
    }

    #[test]
    fn int8_blocked_matches_reference_on_ragged_shapes() {
        // nothing divides the microkernel tile on any of these; odd k
        // exercises the SIMD kernels' zero-padded k-pair path
        for &(m, k, n) in &[(5usize, 7usize, 9usize), (1, 13, 4), (17, 33, 23), (8, 8, 8)] {
            let a = randi8(m * k, (m * 1000 + k) as u64);
            let b = randi8(k * n, (k * 1000 + n) as u64);
            let mut blocked = vec![0i32; m * n];
            let mut reference = vec![0i32; m * n];
            matmul_i8_into(&a, &b, m, k, n, &mut blocked);
            matmul_i8_reference(&a, &b, m, k, n, &mut reference);
            assert_eq!(blocked, reference, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn int8_threaded_matches_single_exactly() {
        let (m, k, n) = (33usize, 21usize, 17usize);
        let a = randi8(m * k, 5);
        let b = randi8(k * n, 6);
        let packed = pack_i8(&b, k, n);
        let mut one = vec![0i32; m * n];
        let mut four = vec![0i32; m * n];
        gemm_i8_packed(&a, &packed, m, &mut one, 1);
        gemm_i8_packed(&a, &packed, m, &mut four, 4);
        assert_eq!(one, four);
    }

    #[test]
    fn int8_scratch_matches_thread_local_path() {
        let (m, k, n) = (9usize, 15usize, 10usize);
        let a = randi8(m * k, 7);
        let b = randi8(k * n, 8);
        let packed = pack_i8(&b, k, n);
        let mut plain = vec![0i32; m * n];
        gemm_i8_packed(&a, &packed, m, &mut plain, 0);
        let mut scratch = crate::util::Scratch::new();
        for _ in 0..2 {
            let mut out = vec![999i32; m * n];
            gemm_i8_packed_scratch(&a, &packed, m, &mut out, &mut scratch);
            assert_eq!(out, plain);
        }
    }

    #[test]
    fn int8_overwrites_stale_output() {
        // gemm_i8_packed stores (doesn't accumulate): stale contents must
        // not leak through
        let a: Vec<i8> = vec![1, 1];
        let b: Vec<i8> = vec![2, 3];
        let mut out = vec![999i32; 2];
        matmul_i8_into(&a, &b, 2, 1, 1, &mut out);
        assert_eq!(out, &[2, 3]);
    }

    #[test]
    fn int8_extreme_values_no_overflow() {
        // all-(-128)·all-(+127) at k=64: the most negative products
        let (m, k, n) = (4usize, 64usize, 8usize);
        let a = vec![-128i8; m * k];
        let b = vec![127i8; k * n];
        let mut out = vec![0i32; m * n];
        matmul_i8_into(&a, &b, m, k, n, &mut out);
        assert!(out.iter().all(|&v| v == -128 * 127 * 64));
    }

    #[test]
    #[should_panic(expected = "overflow bound")]
    fn int8_rejects_overflow_prone_k_in_release_too() {
        let k = I8_GEMM_MAX_K + 1;
        let a = vec![0i8; k];
        let b = pack_i8(&vec![0i8; k], k, 1);
        let mut out = vec![0i32; 1];
        gemm_i8_packed(&a, &b, 1, &mut out, 1);
    }

    #[test]
    fn gemm_thread_cap_bounds_auto_only() {
        // the cap bounds auto-threading but never forces threading onto
        // tiny products, and a hard override wins over the cap
        set_gemm_thread_cap(2);
        assert_eq!(gemm_thread_cap(), 2);
        // tiny product: auto stays 1 (flops guard) regardless of cap
        assert_eq!(gemm_auto_threads(8, 8, 8), 1);
        // big product: auto is clamped to the cap
        assert!(gemm_auto_threads(1024, 1024, 1024) <= 2);
        set_gemm_threads(5);
        assert_eq!(gemm_auto_threads(1024, 1024, 1024), 5);
        set_gemm_threads(0);
        set_gemm_thread_cap(0);
        assert_eq!(gemm_thread_cap(), 0);
        // uncapped auto is bounded by the machine (and the env ceiling)
        let auto = gemm_auto_threads(1024, 1024, 1024);
        let cores = std::thread::available_parallelism().map_or(1, |v| v.get());
        assert!(auto >= 1 && auto <= cores);
        // capped runs stay bitwise identical — only scheduling changes
        let a = Tensor::from_vec(&[33, 21], (0..693).map(|v| (v as f32).sin()).collect()).unwrap();
        let b = Tensor::from_vec(&[21, 17], (0..357).map(|v| (v as f32).cos()).collect()).unwrap();
        let free = matmul(&a, &b).unwrap();
        set_gemm_thread_cap(1);
        let capped = matmul(&a, &b).unwrap();
        set_gemm_thread_cap(0);
        for (x, y) in free.data().iter().zip(capped.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn transpose2_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.transpose2().unwrap();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
        assert_eq!(tt.transpose2().unwrap().data(), t.data());
    }

    #[test]
    fn assign_add_scaled_matches_add() {
        let a = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[4], vec![0.5, -0.5, 1.0, 0.0]).unwrap();
        let mut out = Tensor::zeros(&[4]);
        out.assign_add_scaled(&a, &b, 2.0).unwrap();
        assert_eq!(out.data(), &[2.0, 1.0, 5.0, 4.0]);
        assert!(out.assign_add_scaled(&a, &Tensor::zeros(&[3]), 1.0).is_err());
    }

    #[test]
    fn top2_orderings() {
        assert_eq!(Tensor::top2(&[3.0, 1.0, 2.0]), (0, 2));
        assert_eq!(Tensor::top2(&[1.0, 3.0, 2.0]), (1, 2));
        assert_eq!(Tensor::top2(&[1.0, 2.0, 3.0]), (2, 1));
        assert_eq!(Tensor::top2(&[5.0, 5.0]), (0, 1));
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(&[4], vec![-2.0, 0.0, 1.0, 3.0]).unwrap();
        assert_eq!(t.min(), -2.0);
        assert_eq!(t.max(), 3.0);
        assert_eq!(t.l2_sq(), 14.0);
    }

    #[test]
    fn sub_shape_check() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[4]);
        assert!(a.sub(&b).is_err());
    }
}
