//! Runtime kernel selection for the GEMMs.
//!
//! The best kernel for the host is picked **once per process** (a
//! `OnceLock`): AVX2/FMA on x86-64 when `is_x86_feature_detected!` says
//! so, NEON on aarch64 (baseline there), the portable scalar kernel
//! everywhere else. `ADAQ_FORCE_SCALAR=1` pins the scalar kernel — the CI
//! forced-scalar leg keeps the fallback green on SIMD hosts.
//!
//! Per-process selection is part of the determinism story: a process
//! never mixes kernels for the same GEMM, so the f32 contract ("bitwise
//! invariant across thread count and batch split *within* a kernel")
//! holds for everything a serve process emits. The int8 kernels are
//! bit-exact across *all* kernels (integer math), so cached int8 results
//! survive even a kernel change between runs.
//!
//! Tests and benches address kernels explicitly through
//! [`crate::tensor::matmul_into_with_kernel`] /
//! [`crate::tensor::gemm_i8_packed_with_kernel`] instead of mutating the
//! process-wide choice — a global override would race across cargo's
//! in-process test threads mid-bitwise-comparison.

use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
use super::kernel::avx2;
#[cfg(target_arch = "aarch64")]
use super::kernel::neon;
use super::kernel::scalar;
use super::pack::PackedI8;

/// f32 row-range kernel: `c[rows r0..r1] += a · b_packed`; the trailing
/// buffer is the kernel's reusable A-pack scratch.
pub(crate) type F32RowsFn =
    fn(&[f32], &[f32], &mut [f32], usize, usize, usize, usize, &mut Vec<f32>);
/// int8 row-range kernel: `c[rows r0..r1] = a · b` (fully overwritten).
pub(crate) type I8RowsFn = fn(&[i8], &PackedI8, &mut [i32], usize, usize, &mut Vec<i8>);

/// One dispatchable kernel pair (f32 + int8) and its tile geometry.
pub(crate) struct GemmKernel {
    pub(crate) name: &'static str,
    /// f32 row-tile height — threaded row chunks align to this.
    pub(crate) mr_f32: usize,
    /// int8 row-tile height.
    pub(crate) mr_i8: usize,
    pub(crate) f32_rows: F32RowsFn,
    pub(crate) i8_rows: I8RowsFn,
}

static SCALAR: GemmKernel = GemmKernel {
    name: "scalar",
    mr_f32: scalar::MR_F32,
    mr_i8: scalar::MR_I8,
    f32_rows: scalar::gemm_rows,
    i8_rows: scalar::gemm_i8_rows,
};

#[cfg(target_arch = "x86_64")]
static AVX2: GemmKernel = GemmKernel {
    name: "avx2",
    mr_f32: avx2::MR_F32,
    mr_i8: avx2::MR_I8,
    f32_rows: avx2::gemm_rows,
    i8_rows: avx2::gemm_i8_rows,
};

#[cfg(target_arch = "aarch64")]
static NEON: GemmKernel = GemmKernel {
    name: "neon",
    mr_f32: neon::MR_F32,
    mr_i8: neon::MR_I8,
    f32_rows: neon::gemm_rows,
    i8_rows: neon::gemm_i8_rows,
};

fn force_scalar() -> bool {
    std::env::var("ADAQ_FORCE_SCALAR").map_or(false, |v| !v.is_empty() && v != "0")
}

/// Best kernel the host supports (ignores the env override).
#[allow(unreachable_code)]
fn detect_best() -> &'static GemmKernel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return &AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return &NEON;
    }
    &SCALAR
}

/// The process-wide kernel, selected once on first use.
pub(crate) fn active() -> &'static GemmKernel {
    static ACTIVE: OnceLock<&'static GemmKernel> = OnceLock::new();
    ACTIVE.get_or_init(|| if force_scalar() { &SCALAR } else { detect_best() })
}

/// Every kernel usable on this host, scalar (the reference) first.
pub(crate) fn available() -> Vec<&'static GemmKernel> {
    #[allow(unused_mut)]
    let mut v = vec![&SCALAR];
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        v.push(&AVX2);
    }
    #[cfg(target_arch = "aarch64")]
    v.push(&NEON);
    v
}

/// Look up a host-usable kernel by name.
pub(crate) fn by_name(name: &str) -> Option<&'static GemmKernel> {
    available().into_iter().find(|k| k.name == name)
}

/// Name of the kernel the process-wide dispatch selected (`"scalar"`,
/// `"avx2"` or `"neon"`): CPU capability at first use, overridden to
/// `"scalar"` by `ADAQ_FORCE_SCALAR=1`. Benches tag their JSON rows with
/// this so perf trajectories compare like with like.
pub fn active_kernel() -> &'static str {
    active().name
}

/// Names of every kernel usable on this host — `"scalar"` always (and
/// first: it is the reference the others are tested against), plus
/// `"avx2"`/`"neon"` when the CPU supports them. The per-kernel test
/// batteries iterate over this.
pub fn kernel_names() -> Vec<&'static str> {
    available().iter().map(|k| k.name).collect()
}
