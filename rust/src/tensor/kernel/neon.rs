//! NEON microkernels (aarch64 — NEON is baseline there, so no runtime
//! feature probe is needed; the dispatcher selects this unconditionally
//! unless `ADAQ_FORCE_SCALAR=1`).
//!
//! **f32** — a 4×8 tile held in eight q-register accumulators (4 rows ×
//! 2 half-panels), updated with `vfmaq_f32` broadcast FMAs from a packed
//! A panel. Like the AVX2 kernel: FMA rounding differs from scalar, but
//! the fixed k-order keeps results bitwise reproducible across thread
//! counts within this kernel.
//!
//! **int8** — exact widening multiply over k-pairs: `vmull_s8` widens
//! i8×i8 products to i16, and `vpadalq_s16` sums adjacent pairs into the
//! i32 accumulators *in wide precision*. Summing the pair in i16 first
//! would overflow ((−128)·(−128) + (−128)·(−128) = 32768 > i16::MAX);
//! the pairwise widening accumulate keeps every input exact, so this
//! kernel is bit-identical to `scalar::gemm_i8_rows`.

use core::arch::aarch64::*;

use crate::tensor::pack::{self, PackedI8, KC, NR};

/// f32 microkernel row tile.
pub(crate) const MR_F32: usize = 4;
/// int8 microkernel row tile.
pub(crate) const MR_I8: usize = 4;

/// Compute C rows [r0, r1): `c += a · b_packed`. `c` holds exactly those
/// rows and must be zeroed; `apack` is the reusable A-panel buffer.
pub(crate) fn gemm_rows(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    apack: &mut Vec<f32>,
) {
    unsafe { gemm_rows_impl(a, packed, c, r0, r1, k, n, apack) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_rows_impl(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    apack: &mut Vec<f32>,
) {
    let npanels = n.div_ceil(NR);
    let mut i = r0;
    while i < r1 {
        let mr = MR_F32.min(r1 - i);
        pack::pack_a_panel(a, i, mr, k, MR_F32, apack);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            let apanel = &apack[pc * MR_F32..(pc + kc) * MR_F32];
            for jp in 0..npanels {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                let panel = &packed[jp * k * NR + pc * NR..jp * k * NR + (pc + kc) * NR];
                let mut acc = [[vdupq_n_f32(0.0); 2]; MR_F32];
                let mut ap = apanel.as_ptr();
                let mut bp = panel.as_ptr();
                for _ in 0..kc {
                    let b0 = vld1q_f32(bp);
                    let b1 = vld1q_f32(bp.add(4));
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let av = vdupq_n_f32(*ap.add(r));
                        accr[0] = vfmaq_f32(accr[0], b0, av);
                        accr[1] = vfmaq_f32(accr[1], b1, av);
                    }
                    ap = ap.add(MR_F32);
                    bp = bp.add(NR);
                }
                if nr == NR {
                    for (r, accr) in acc.iter().enumerate().take(mr) {
                        let cp = c.as_mut_ptr().add((i + r - r0) * n + j0);
                        vst1q_f32(cp, vaddq_f32(vld1q_f32(cp), accr[0]));
                        let cp4 = cp.add(4);
                        vst1q_f32(cp4, vaddq_f32(vld1q_f32(cp4), accr[1]));
                    }
                } else {
                    let mut tmp = [0f32; NR];
                    for (r, accr) in acc.iter().enumerate().take(mr) {
                        vst1q_f32(tmp.as_mut_ptr(), accr[0]);
                        vst1q_f32(tmp.as_mut_ptr().add(4), accr[1]);
                        let off = (i + r - r0) * n + j0;
                        for j in 0..nr {
                            c[off + j] += tmp[j];
                        }
                    }
                }
            }
            pc += kc;
        }
        i += mr;
    }
}

/// int8×int8→i32 rows [r0, r1); `c` is fully overwritten. Bit-exact
/// against the scalar kernel by construction (see module docs).
pub(crate) fn gemm_i8_rows(
    a: &[i8],
    b: &PackedI8,
    c: &mut [i32],
    r0: usize,
    r1: usize,
    apack: &mut Vec<i8>,
) {
    unsafe { gemm_i8_rows_impl(a, b, c, r0, r1, apack) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_i8_rows_impl(
    a: &[i8],
    b: &PackedI8,
    c: &mut [i32],
    r0: usize,
    r1: usize,
    apack: &mut Vec<i8>,
) {
    let (k, n, ks) = (b.k, b.n, b.kstride);
    let packed = &b.panels[..];
    let npanels = n.div_ceil(NR);
    // kstride is even with zero pad rows: whole k-pairs, no tail load
    let kp = ks / 2;
    let mut i = r0;
    while i < r1 {
        let mr = MR_I8.min(r1 - i);
        pack::pack_a_i8_panel(a, i, mr, k, MR_I8, apack);
        for jp in 0..npanels {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let panel = &packed[jp * ks * NR..(jp + 1) * ks * NR];
            // acc[r][h]: i32 lanes for columns h*4 .. h*4+4
            let mut acc = [[vdupq_n_s32(0); 2]; MR_I8];
            let mut ap = apack.as_ptr();
            let mut bp = panel.as_ptr();
            for _ in 0..kp {
                // [b_p | b_{p+1}] (2×NR bytes) → per-column pair zip:
                // zip.0 = [b_p[0], b_{p+1}[0], …, b_p[3], b_{p+1}[3]]
                let bytes = vld1q_s8(bp);
                let zip = vzip_s8(vget_low_s8(bytes), vget_high_s8(bytes));
                for (r, accr) in acc.iter_mut().enumerate() {
                    // [a_p, a_{p+1}] repeated in every i16 lane
                    let pair =
                        i16::from_le_bytes([*ap.add(r * 2) as u8, *ap.add(r * 2 + 1) as u8]);
                    let av = vreinterpret_s8_s16(vdup_n_s16(pair));
                    accr[0] = vpadalq_s16(accr[0], vmull_s8(zip.0, av));
                    accr[1] = vpadalq_s16(accr[1], vmull_s8(zip.1, av));
                }
                ap = ap.add(MR_I8 * 2);
                bp = bp.add(NR * 2);
            }
            if nr == NR {
                for (r, accr) in acc.iter().enumerate().take(mr) {
                    let cp = c.as_mut_ptr().add((i + r - r0) * n + j0);
                    vst1q_s32(cp, accr[0]);
                    vst1q_s32(cp.add(4), accr[1]);
                }
            } else {
                let mut tmp = [0i32; NR];
                for (r, accr) in acc.iter().enumerate().take(mr) {
                    vst1q_s32(tmp.as_mut_ptr(), accr[0]);
                    vst1q_s32(tmp.as_mut_ptr().add(4), accr[1]);
                    let off = (i + r - r0) * n + j0;
                    c[off..off + nr].copy_from_slice(&tmp[..nr]);
                }
            }
        }
        i += mr;
    }
}
