//! Portable scalar microkernels — the dispatch fallback on hosts without
//! AVX2/NEON (`ADAQ_FORCE_SCALAR=1` forces them everywhere) and the
//! correctness reference the SIMD kernels are tested against: the int8
//! SIMD kernels must match `gemm_i8_rows` **bit-exactly**, the f32 ones
//! within tolerance (FMA contraction rounds differently).
//!
//! These are the seed's kernels unchanged: MR×NR register-tiled, no
//! explicit intrinsics, relying on the autovectorizer (the release
//! profile keeps `codegen-units = 1` so the whole loop nest is visible to
//! it). They read A directly — at MR=4 the strided loads are four
//! sequential streams, which the prefetcher handles; the SIMD kernels pack
//! A instead to feed their broadcast loads from one cache line.

use crate::tensor::pack::{PackedI8, KC, NR};

/// f32 microkernel row tile.
pub(crate) const MR_F32: usize = 4;
/// int8 microkernel row tile.
pub(crate) const MR_I8: usize = 4;

/// Compute C rows [r0, r1) from A and packed B: `c += a · b_packed`.
/// `c` holds exactly those rows (row r0 of the full matrix is row 0 of
/// `c`) and must be zeroed. `_apack` is unused — this kernel reads A in
/// place.
pub(crate) fn gemm_rows(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    _apack: &mut Vec<f32>,
) {
    let npanels = n.div_ceil(NR);
    let mut i = r0;
    while i < r1 {
        let mr = MR_F32.min(r1 - i);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            for jp in 0..npanels {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                let panel = &packed[jp * k * NR + pc * NR..jp * k * NR + (pc + kc) * NR];
                // register-tiled MR×NR accumulator block
                let mut acc = [[0f32; NR]; MR_F32];
                for p in 0..kc {
                    let brow = &panel[p * NR..p * NR + NR];
                    for r in 0..mr {
                        let av = a[(i + r) * k + pc + p];
                        let accr = &mut acc[r];
                        for j in 0..NR {
                            accr[j] += av * brow[j];
                        }
                    }
                }
                for r in 0..mr {
                    let off = (i + r - r0) * n + j0;
                    let crow = &mut c[off..off + nr];
                    for (cv, &av) in crow.iter_mut().zip(&acc[r][..nr]) {
                        *cv += av;
                    }
                }
            }
            pc += kc;
        }
        i += mr;
    }
}

/// int8×int8→i32 GEMM rows [r0, r1) from A and a packed B. `c` holds
/// exactly those rows and is fully overwritten (no zeroing needed).
/// `_apack` is unused — this kernel reads A in place.
pub(crate) fn gemm_i8_rows(
    a: &[i8],
    b: &PackedI8,
    c: &mut [i32],
    r0: usize,
    r1: usize,
    _apack: &mut Vec<i8>,
) {
    let (k, n, ks) = (b.k, b.n, b.kstride);
    let packed = &b.panels[..];
    let npanels = n.div_ceil(NR);
    let mut i = r0;
    while i < r1 {
        let mr = MR_I8.min(r1 - i);
        for jp in 0..npanels {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            // panel rows k..kstride are zero padding; this kernel never
            // reads them, the pair-based SIMD kernels do (×0, exact)
            let panel = &packed[jp * ks * NR..jp * ks * NR + k * NR];
            // register-tiled MR×NR i32 accumulator block over the full k
            let mut acc = [[0i32; NR]; MR_I8];
            for p in 0..k {
                let brow = &panel[p * NR..p * NR + NR];
                for r in 0..mr {
                    let av = a[(i + r) * k + p] as i32;
                    let accr = &mut acc[r];
                    for j in 0..NR {
                        accr[j] += av * brow[j] as i32;
                    }
                }
            }
            for r in 0..mr {
                let off = (i + r - r0) * n + j0;
                c[off..off + nr].copy_from_slice(&acc[r][..nr]);
            }
        }
        i += mr;
    }
}
