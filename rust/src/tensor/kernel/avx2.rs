//! AVX2/FMA microkernels (x86-64, runtime-detected by the dispatcher).
//!
//! **f32** — an 8×8 register tile: eight ymm accumulators, one per A row,
//! each updated with a broadcast-A × panel-row FMA per k-step. Eight
//! independent accumulation chains keep both FMA ports busy across the
//! ~4-cycle FMA latency. A is packed per row-panel
//! (`pack_a_panel`), so each k-step broadcasts all MR values from one
//! cache line. FMA contracts multiply-add into a single rounding — the
//! results differ from the scalar kernel in the last ulp — but the
//! per-element k-order is fixed exactly like the scalar kernel (ascending
//! p within KC blocks, blocks ascending), so results are bitwise
//! reproducible across thread counts and batch splits *within* this
//! kernel.
//!
//! **int8** — exact widening multiply over k-pairs. The classic
//! `pmaddubsw` u8×s8 path *saturates* its i16 pair-sums for full-range
//! inputs (e.g. (−128)·(−128) + (−128)·(−128) = 32768 > i16::MAX), which
//! would break the bit-exactness contract against the scalar kernel.
//! Instead both operands are sign-extended to i16 and multiplied with
//! `pmaddwd` (`_mm256_madd_epi16`): i16×i16 products summed pairwise into
//! i32 are exact for every input, so this kernel is bit-identical to
//! `scalar::gemm_i8_rows` — integer addition is associative, the pair
//! regrouping changes nothing.

use core::arch::x86_64::*;

use crate::tensor::pack::{self, PackedI8, KC, NR};

/// f32 microkernel row tile (8 ymm accumulators).
pub(crate) const MR_F32: usize = 8;
/// int8 microkernel row tile.
pub(crate) const MR_I8: usize = 4;

/// Compute C rows [r0, r1): `c += a · b_packed`. `c` holds exactly those
/// rows and must be zeroed; `apack` is the reusable A-panel buffer.
///
/// Safety contract (checked by the dispatcher, not here): only selected
/// after `is_x86_feature_detected!("avx2")` and `("fma")` both pass.
pub(crate) fn gemm_rows(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    apack: &mut Vec<f32>,
) {
    unsafe { gemm_rows_impl(a, packed, c, r0, r1, k, n, apack) }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn gemm_rows_impl(
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    apack: &mut Vec<f32>,
) {
    let npanels = n.div_ceil(NR);
    let mut i = r0;
    while i < r1 {
        let mr = MR_F32.min(r1 - i);
        // pack this row-panel of A k-major (edge rows zero-padded): the
        // kernel always computes a full 8-row tile, writes back `mr`
        pack::pack_a_panel(a, i, mr, k, MR_F32, apack);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            let apanel = &apack[pc * MR_F32..(pc + kc) * MR_F32];
            for jp in 0..npanels {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                let panel = &packed[jp * k * NR + pc * NR..jp * k * NR + (pc + kc) * NR];
                let mut acc = [_mm256_setzero_ps(); MR_F32];
                let mut ap = apanel.as_ptr();
                let mut bp = panel.as_ptr();
                for _ in 0..kc {
                    let bv = _mm256_loadu_ps(bp);
                    acc[0] = _mm256_fmadd_ps(_mm256_set1_ps(*ap), bv, acc[0]);
                    acc[1] = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(1)), bv, acc[1]);
                    acc[2] = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(2)), bv, acc[2]);
                    acc[3] = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(3)), bv, acc[3]);
                    acc[4] = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(4)), bv, acc[4]);
                    acc[5] = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(5)), bv, acc[5]);
                    acc[6] = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(6)), bv, acc[6]);
                    acc[7] = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(7)), bv, acc[7]);
                    ap = ap.add(MR_F32);
                    bp = bp.add(NR);
                }
                if nr == NR {
                    for (r, &av) in acc.iter().enumerate().take(mr) {
                        let cp = c.as_mut_ptr().add((i + r - r0) * n + j0);
                        _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), av));
                    }
                } else {
                    let mut tmp = [0f32; NR];
                    for (r, &av) in acc.iter().enumerate().take(mr) {
                        _mm256_storeu_ps(tmp.as_mut_ptr(), av);
                        let off = (i + r - r0) * n + j0;
                        for j in 0..nr {
                            c[off + j] += tmp[j];
                        }
                    }
                }
            }
            pc += kc;
        }
        i += mr;
    }
}

/// int8×int8→i32 rows [r0, r1); `c` is fully overwritten. Bit-exact
/// against the scalar kernel by construction (see module docs).
///
/// Safety contract: only selected after `is_x86_feature_detected!("avx2")`.
pub(crate) fn gemm_i8_rows(
    a: &[i8],
    b: &PackedI8,
    c: &mut [i32],
    r0: usize,
    r1: usize,
    apack: &mut Vec<i8>,
) {
    unsafe { gemm_i8_rows_impl(a, b, c, r0, r1, apack) }
}

#[target_feature(enable = "avx2")]
unsafe fn gemm_i8_rows_impl(
    a: &[i8],
    b: &PackedI8,
    c: &mut [i32],
    r0: usize,
    r1: usize,
    apack: &mut Vec<i8>,
) {
    let (k, n, ks) = (b.k, b.n, b.kstride);
    let packed = &b.panels[..];
    let npanels = n.div_ceil(NR);
    // kstride is even and rows k..kstride are zero, so every panel is
    // whole k-pairs: the ×0 pad terms keep odd k exact with no tail load
    let kp = ks / 2;
    let mut i = r0;
    while i < r1 {
        let mr = MR_I8.min(r1 - i);
        pack::pack_a_i8_panel(a, i, mr, k, MR_I8, apack);
        for jp in 0..npanels {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let panel = &packed[jp * ks * NR..(jp + 1) * ks * NR];
            let mut acc = [_mm256_setzero_si256(); MR_I8];
            let mut ap = apack.as_ptr();
            let mut bp = panel.as_ptr();
            for _ in 0..kp {
                // [b_p | b_{p+1}] (2×NR bytes) → per-column pair
                // interleave → sign-extend to 16×i16
                let bytes = _mm_loadu_si128(bp as *const __m128i);
                let inter = _mm_unpacklo_epi8(bytes, _mm_srli_si128(bytes, 8));
                let bv = _mm256_cvtepi8_epi16(inter);
                // per row: both pair values as adjacent i16s in every i32
                // lane; pmaddwd then yields b_p[j]·a_p + b_{p+1}[j]·a_{p+1}
                let mut aprs = [0i32; MR_I8];
                for (r, apr) in aprs.iter_mut().enumerate() {
                    let a0 = *ap.add(r * 2) as i16 as u16 as u32;
                    let a1 = *ap.add(r * 2 + 1) as i16 as u16 as u32;
                    *apr = (a0 | (a1 << 16)) as i32;
                }
                let av0 = _mm256_set1_epi32(aprs[0]);
                let av1 = _mm256_set1_epi32(aprs[1]);
                let av2 = _mm256_set1_epi32(aprs[2]);
                let av3 = _mm256_set1_epi32(aprs[3]);
                acc[0] = _mm256_add_epi32(acc[0], _mm256_madd_epi16(bv, av0));
                acc[1] = _mm256_add_epi32(acc[1], _mm256_madd_epi16(bv, av1));
                acc[2] = _mm256_add_epi32(acc[2], _mm256_madd_epi16(bv, av2));
                acc[3] = _mm256_add_epi32(acc[3], _mm256_madd_epi16(bv, av3));
                ap = ap.add(MR_I8 * 2);
                bp = bp.add(NR * 2);
            }
            if nr == NR {
                for (r, &av) in acc.iter().enumerate().take(mr) {
                    let cp = c.as_mut_ptr().add((i + r - r0) * n + j0);
                    _mm256_storeu_si256(cp as *mut __m256i, av);
                }
            } else {
                let mut tmp = [0i32; NR];
                for (r, &av) in acc.iter().enumerate().take(mr) {
                    _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, av);
                    let off = (i + r - r0) * n + j0;
                    c[off..off + nr].copy_from_slice(&tmp[..nr]);
                }
            }
        }
        i += mr;
    }
}
