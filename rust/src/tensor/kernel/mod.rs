//! GEMM microkernels. One module per instruction set; all consume the
//! shared packed-panel formats from [`crate::tensor::pack`] and are
//! selected at runtime by [`crate::tensor::dispatch`].

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;
pub(crate) mod scalar;
