//! EQ3 — validate the quantization-noise model of Eq. 3 on *real trained
//! weights*: measured ‖r_W‖² vs the analytic p′·e^(−α·b), per layer and
//! bit-width; the 4×-per-bit (6 dB/bit) law.
//!
//! Paper reference: §Quantization noise, Eq. 3 (and the supplementary
//! derivation). Expected shape: measured/predicted ≈ 1 within ~20 % for
//! well-spread weight distributions, ratio between consecutive bit-widths
//! ≈ 4.

use adaq::bench_support as bs;
use adaq::io::csv::CsvWriter;
use adaq::model::ModelArtifacts;
use adaq::quant::{quant_noise, NoiseModel};
use adaq::report::{markdown_table, Align};

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let root = bs::artifacts_root();
    let dir = bs::report_dir("eq3_noise_model");
    let mut report = String::from("# EQ3 — quantization-noise model (Eq. 3)\n\n");
    for model in bs::bench_models() {
        let arts = match ModelArtifacts::load(&root, &model) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let mut csv = CsvWriter::create(
            dir.join(format!("{model}.csv")),
            &["qindex", "bits", "measured", "predicted", "ratio_to_prev_bit"],
        )
        .unwrap();
        let mut rows = Vec::new();
        for layer in arts.manifest.weighted_layers() {
            let qi = layer.qindex.unwrap();
            let w = arts.weights.weight(&layer.name).unwrap();
            let nm = NoiseModel::of(w);
            let mut prev = f64::NAN;
            for bits in [4.0f64, 6.0, 8.0, 10.0] {
                let measured = quant_noise(w, bits as f32);
                let predicted = nm.expected(bits);
                let ratio = prev / measured;
                csv.row(&[qi as f64, bits, measured, predicted, ratio]).unwrap();
                if bits == 8.0 {
                    rows.push(vec![
                        layer.name.clone(),
                        format!("{measured:.4e}"),
                        format!("{predicted:.4e}"),
                        format!("{:.3}", measured / predicted),
                        format!("{ratio:.2}"),
                    ]);
                }
                prev = measured;
            }
        }
        csv.flush().unwrap();
        let table = markdown_table(
            // bits ladder steps by 2 → the 4×/bit law shows as ≈16 between
            // consecutive rows
            &["layer", "measured@8b", "predicted@8b", "meas/pred", "4²-law (6b/8b ≈ 16)"],
            &[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right],
            &rows,
        );
        println!("\n== {model} ==\n{table}");
        report.push_str(&format!("## {model}\n\n{table}\n"));
    }
    report.push_str(
        "\nExpected: meas/pred ≈ 1 (uniform-noise approximation), the \
         bit-to-bit ratio ≈ 4 (6 dB/bit, Gray & Neuhoff).\n",
    );
    bs::write_report("eq3_noise_model", &report);
}
