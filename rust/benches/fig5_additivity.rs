//! Fig. 5 — additivity of the measurement: Σᵢ‖r_{Z_i}‖² (per-layer
//! quantization, host-side) vs ‖r_Z‖² (all layers quantized at once
//! through the Pallas qforward path), across bit-widths.
//!
//! Expected shape (paper): equality in the small-noise (high-bit) regime;
//! visible deviation only at very low bit-widths, where accuracy is
//! already near chance.

use adaq::bench_support as bs;
use adaq::io::csv::CsvWriter;
use adaq::measure::additivity_probe;
use adaq::report::{ascii_plot, markdown_table, Align, Series};

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let dir = bs::report_dir("fig5_additivity");
    let bit_widths = [2.0f64, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0];
    let mut report = String::from("# Fig. 5 — additivity of ‖r_Z‖²\n\n");
    for model in bs::bench_models() {
        let (session, _cal) = match bs::session_with_calibration(&model) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let points = additivity_probe(&session, &bit_widths).unwrap();
        let mut csv = CsvWriter::create(
            dir.join(format!("{model}.csv")),
            &["bits", "sum_individual", "joint", "rw_sq", "joint_accuracy"],
        )
        .unwrap();
        let mut rows = Vec::new();
        for p in &points {
            csv.row(&[p.bits, p.sum_individual, p.joint, p.rw_sq, p.joint_accuracy])
                .unwrap();
            rows.push(vec![
                format!("{}", p.bits),
                format!("{:.4e}", p.sum_individual),
                format!("{:.4e}", p.joint),
                format!("{:.3}", p.joint / p.sum_individual),
                format!("{:.4}", p.joint_accuracy),
            ]);
        }
        csv.flush().unwrap();
        let series = vec![
            Series::new(
                "joint vs sum",
                'o',
                points.iter().map(|p| (p.sum_individual, p.joint)).collect(),
            ),
            Series::new(
                "y = x",
                '.',
                points
                    .iter()
                    .map(|p| (p.sum_individual, p.sum_individual))
                    .collect(),
            ),
        ];
        let plot = ascii_plot(
            &format!("{model}: Σ‖r_Zi‖² vs ‖r_Z‖² (log-log)"),
            &series,
            64,
            18,
            true,
            true,
        );
        let table = markdown_table(
            &["bits", "Σ individual", "joint", "joint/Σ", "joint acc"],
            &[Align::Right; 5],
            &rows,
        );
        println!("\n== {model} ==\n{table}\n{plot}");
        report.push_str(&format!("## {model}\n\n{table}\n```\n{plot}```\n\n"));
    }
    report.push_str(
        "\nExpected: joint/Σ ≈ 1 for ≥4 bits; deviations appear only where \
         joint accuracy has already collapsed (paper Fig. 5 text).\n",
    );
    bs::write_report("fig5_additivity", &report);
}
