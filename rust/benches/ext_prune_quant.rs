//! Extension — composing adaptive quantization with magnitude pruning
//! (the paper's conclusion: the two compress "without interfering"; Han,
//! Mao & Dally 2015). For each pruning level, prune host-side, re-quantize
//! with the adaptive allocation, and report accuracy + CSR-style size
//! (b value bits + 4 relative-index bits per surviving weight).
//!
//! Deliberate scope cut (recorded in EXPERIMENTS.md): Deep Compression
//! *retrains* between the pruning and quantization stages; our pipeline
//! is strictly post-training, so this bench measures the composition
//! *without* retraining — expect the interference to appear much earlier
//! (tens of percent pruning) than the paper's retrained 90 %+. The bench
//! exists to quantify exactly that gap.

use adaq::bench_support as bs;
use adaq::io::csv::CsvWriter;
use adaq::quant::{fake_quant, magnitude_prune, pruned_quantized_bits, Allocator};
use adaq::report::{markdown_table, Align};

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let dir = bs::report_dir("ext_prune_quant");
    let mut report = String::from("# Extension — pruning × adaptive quantization\n\n");
    for model in bs::bench_models() {
        let (session, cal) = match bs::session_with_calibration(&model) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let stats = cal.layer_stats();
        let nwl = stats.len();
        let alloc = Allocator::Adaptive.allocate(&stats, 8.0, &vec![true; nwl], 16.0);
        let fp32_bits = session.artifacts.manifest.fp32_bytes() * 8.0;

        let mut csv = CsvWriter::create(
            dir.join(format!("{model}.csv")),
            &["prune_frac", "accuracy", "size_kib", "compression_x"],
        )
        .unwrap();
        let mut rows = Vec::new();
        for frac in [0.0f64, 0.3, 0.5, 0.7, 0.9] {
            let mut overrides_data = Vec::new();
            let mut size_bits = 0f64;
            for qi in 0..nwl {
                let (pidx, w) = session.layer_weight(qi).unwrap();
                let b = alloc.bits[qi];
                let pruned = magnitude_prune(w, frac);
                let quantized = fake_quant(&pruned, b as f32);
                size_bits += if frac > 0.0 {
                    pruned_quantized_bits(&pruned, b, 4.0)
                } else {
                    stats[qi].s * b
                };
                overrides_data.push((pidx, quantized));
            }
            let overrides: Vec<(usize, &adaq::tensor::Tensor)> =
                overrides_data.iter().map(|(p, t)| (*p, t)).collect();
            let out = session.eval_with_overrides(&overrides).unwrap();
            let comp = fp32_bits / size_bits;
            csv.row(&[frac, out.accuracy, size_bits / 8192.0, comp]).unwrap();
            rows.push(vec![
                format!("{:.0}%", frac * 100.0),
                format!("{:.4}", out.accuracy),
                format!("{:.1}", size_bits / 8192.0),
                format!("{comp:.1}x"),
            ]);
        }
        csv.flush().unwrap();
        let table = markdown_table(
            &["pruned", "accuracy", "size KiB", "vs fp32"],
            &[Align::Right; 4],
            &rows,
        );
        println!(
            "\n== {model} (baseline acc {:.4}) ==\n{table}",
            session.baseline().accuracy
        );
        report.push_str(&format!(
            "## {model} (baseline {:.4})\n\n{table}\n",
            session.baseline().accuracy
        ));
    }
    bs::write_report("ext_prune_quant", &report);
}
