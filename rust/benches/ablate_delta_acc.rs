//! Ablation — Δacc-independence of the calibration (the paper's claim
//! under Eq. 13/22: "the selected value of Δacc does not matter for the
//! optimization result, as long as t_i(Δacc)/t_j(Δacc) is almost
//! independent w.r.t. Δacc").
//!
//! We calibrate t_i at two different Δacc values and compare (a) the
//! normalized t-ratios and (b) the resulting adaptive bit allocations —
//! both should agree up to a uniform shift.

use adaq::bench_support as bs;
use adaq::coordinator::Session;
use adaq::measure::{calibrate_model, SearchParams};
use adaq::quant::Allocator;
use adaq::report::{markdown_table, Align};

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let model = bs::bench_models()
        .first()
        .cloned()
        .unwrap_or_else(|| "mini_alexnet".into());
    let session = Session::open(bs::artifacts_root(), &model, bs::bench_batch()).unwrap();
    let base = session.baseline().accuracy;
    let sp = SearchParams { seeds: 1, ..Default::default() };

    let deltas = [base * 0.25, base * 0.5];
    let mut cals = Vec::new();
    for &d in &deltas {
        eprintln!("[bench] calibrating {model} at Δacc = {d:.3}");
        cals.push(calibrate_model(&session, d, &sp, |_| {}).unwrap());
    }

    // compare normalized log t-ratios and allocations
    let stats_a = cals[0].layer_stats();
    let stats_b = cals[1].layer_stats();
    let mask = vec![true; stats_a.len()];
    let alloc_a = Allocator::Adaptive.allocate(&stats_a, 8.0, &mask, 16.0);
    let alloc_b = Allocator::Adaptive.allocate(&stats_b, 8.0, &mask, 16.0);

    let mut rows = Vec::new();
    let t0a = cals[0].layers[0].t;
    let t0b = cals[1].layers[0].t;
    let mut max_bit_dev = 0f64;
    // allocations agree up to a uniform shift: compare deviations around
    // the mean difference
    let mean_shift: f64 = alloc_a
        .bits
        .iter()
        .zip(&alloc_b.bits)
        .map(|(a, b)| a - b)
        .sum::<f64>()
        / alloc_a.bits.len() as f64;
    for (i, layer) in cals[0].layers.iter().enumerate() {
        let ra = layer.t / t0a;
        let rb = cals[1].layers[i].t / t0b;
        let bit_dev = (alloc_a.bits[i] - alloc_b.bits[i] - mean_shift).abs();
        max_bit_dev = max_bit_dev.max(bit_dev);
        rows.push(vec![
            layer.name.clone(),
            format!("{:.3}", ra),
            format!("{:.3}", rb),
            format!("{:.2}", ra / rb),
            format!("{:.2}", bit_dev),
        ]);
    }
    let table = markdown_table(
        &[
            "layer",
            &format!("t_i/t_1 @Δ={:.2}", deltas[0]),
            &format!("t_i/t_1 @Δ={:.2}", deltas[1]),
            "ratio",
            "bit dev",
        ],
        &[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right],
        &rows,
    );
    println!("\n== {model} ==\n{table}");
    println!(
        "max per-layer allocation deviation after uniform shift: {max_bit_dev:.2} bits \
         (paper's claim: ≈0; <1 bit is within rounding)"
    );
    bs::write_report(
        "ablate_delta_acc",
        &format!(
            "# Ablation — Δacc independence (Eq. 22 remark)\n\n## {model}\n\n{table}\n\
             max per-layer allocation deviation after uniform shift: {max_bit_dev:.2} bits.\n"
        ),
    );
}
