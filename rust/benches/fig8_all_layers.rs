//! Fig. 8 (supplementary) — model size vs accuracy with **all** weighted
//! layers quantized (conv + FC).
//!
//! Expected shape: same ordering as Fig. 6 with a larger adaptive margin
//! on FC-heavy models (the paper reports ~40% smaller at matched accuracy
//! for AlexNet/VGG, 15-20% for GoogLeNet/ResNet-50).

fn main() {
    adaq::bench_support::run_figure_sweep(
        "fig8_all_layers",
        false,
        "Fig. 8 — size vs accuracy (all layers quantized)",
    );
}
