//! Fig. 6 — model size vs accuracy, **convolutional layers only** (FC
//! frozen at 16 bits, matching the paper's comparison protocol against
//! the SQNR method, which does not handle FC layers).
//!
//! Expected shape: adaptive dominates SQNR dominates equal, with SQNR's
//! edge over equal vanishing on the 1×1-bottleneck model (mini_resnet) —
//! the paper's Fig. 6 discussion point.

fn main() {
    adaq::bench_support::run_figure_sweep(
        "fig6_conv_only",
        true,
        "Fig. 6 — size vs accuracy (conv layers quantized, FC @ 16 bits)",
    );
}
