//! Fig. 4 — linearity of noise transfer: ‖r_{W_i}‖² vs ‖r_{Z_i}‖² per
//! layer over a geometric ladder of noise scales.
//!
//! Expected shape (paper): linear in the small-noise regime (Pearson ≈ 1
//! on the lower half), curves for *earlier* layers bend away from
//! linearity first at large noise (they pass through more ReLU/pool
//! non-linearities) — and by then accuracy has already collapsed.

use adaq::bench_support as bs;
use adaq::io::csv::CsvWriter;
use adaq::measure::linearity_probe;
use adaq::report::{ascii_plot, markdown_table, Align, Series};

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let dir = bs::report_dir("fig4_linearity");
    let ks: Vec<f64> = (0..10).map(|i| 1e-3 * 4f64.powi(i)).collect();
    let mut report = String::from("# Fig. 4 — ‖r_W‖² vs ‖r_Z‖² linearity\n\n");
    for model in bs::bench_models() {
        let (session, _cal) = match bs::session_with_calibration(&model) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let nwl = session.artifacts.manifest.num_weighted_layers;
        // probe first / middle / last layers (the paper's panels)
        let probes: Vec<usize> = {
            let mut v = vec![0, nwl / 2, nwl - 1];
            v.dedup();
            v
        };
        let mut csv = CsvWriter::create(
            dir.join(format!("{model}.csv")),
            &["qindex", "rw_sq", "rz_sq", "accuracy"],
        )
        .unwrap();
        let mut series = Vec::new();
        let mut rows = Vec::new();
        let markers = ['o', '*', 'x'];
        for (i, &qi) in probes.iter().enumerate() {
            let curve = linearity_probe(&session, qi, &ks, 7).unwrap();
            for &(rw, rz, acc) in &curve.points {
                csv.row(&[qi as f64, rw, rz, acc]).unwrap();
            }
            rows.push(vec![
                curve.layer.clone(),
                format!("{:.5}", curve.small_noise_pearson),
                format!("{:.4}", curve.points.last().unwrap().2),
            ]);
            series.push(Series::new(
                curve.layer.clone(),
                markers[i % markers.len()],
                curve.points.iter().map(|&(rw, rz, _)| (rw, rz)).collect(),
            ));
        }
        csv.flush().unwrap();
        let plot = ascii_plot(
            &format!("{model}: ‖r_W‖² vs ‖r_Z‖² (log-log)"),
            &series,
            64,
            20,
            true,
            true,
        );
        let table = markdown_table(
            &["layer", "small-noise Pearson r", "acc @ max noise"],
            &[Align::Left, Align::Right, Align::Right],
            &rows,
        );
        println!("{plot}\n{table}");
        report.push_str(&format!("## {model}\n\n{table}\n```\n{plot}```\n\n"));
    }
    report.push_str(
        "\nExpected: Pearson ≈ 1 in the small-noise half; by the time \
         curves bend, accuracy has already collapsed (paper Fig. 4 text).\n",
    );
    bs::write_report("fig4_linearity", &report);
}
