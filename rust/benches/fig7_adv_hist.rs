//! Fig. 7 (supplementary) — histogram of the per-sample adversarial-noise
//! norm ‖r*‖² = (z₍₁₎−z₍₂₎)²/2 on the last feature map, plus mean_r*
//! (the paper reports mean 5.33 for AlexNet/ImageNet; ours differs in
//! absolute value — different net + data — but the right-skewed shape is
//! the reproduced property).

use adaq::bench_support as bs;
use adaq::io::csv::CsvWriter;
use adaq::measure::adversarial_stats;
use adaq::report::ascii_histogram;

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let dir = bs::report_dir("fig7_adv_hist");
    let mut report = String::from("# Fig. 7 — histogram of ‖r*‖²\n\n");
    for model in bs::bench_models() {
        let (session, _cal) = match bs::session_with_calibration(&model) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let st = adversarial_stats(&session, 20);
        let mut csv = CsvWriter::create(
            dir.join(format!("{model}.csv")),
            &["bin_lo", "bin_hi", "count"],
        )
        .unwrap();
        for (i, &c) in st.hist_counts.iter().enumerate() {
            csv.row(&[st.hist_edges[i], st.hist_edges[i + 1], c as f64]).unwrap();
        }
        csv.flush().unwrap();
        let h = ascii_histogram(
            &format!(
                "{model}: ‖r*‖² (mean {:.3}, median {:.3}, max {:.3})",
                st.mean_rstar, st.median_rstar, st.max_rstar
            ),
            &st.hist_edges,
            &st.hist_counts,
            40,
        );
        println!("\n{h}");
        report.push_str(&format!("## {model}\n\n```\n{h}```\n\n"));
    }
    report.push_str("\nExpected: right-skewed margin distribution (paper Fig. 7).\n");
    bs::write_report("fig7_adv_hist", &report);
}
