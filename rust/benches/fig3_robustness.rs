//! Fig. 3 — ‖r_{Z_i}‖² vs model accuracy per layer, and the extracted t_i
//! values at Δacc (paper §Calculate t_i: t₁…t₆ ≈ const, t₇/t₈ larger).
//!
//! Data source: the binary-search curves recorded during calibration
//! (Alg. 1); this bench re-runs calibration if no calibration.json is
//! cached, then renders the ‖r_Z‖²–accuracy relationship per layer.

use adaq::bench_support as bs;
use adaq::io::csv::CsvWriter;
use adaq::report::{ascii_plot, markdown_table, Align, Series};

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let dir = bs::report_dir("fig3_robustness");
    let mut report = String::from("# Fig. 3 — per-layer robustness curves and t_i\n\n");
    for model in bs::bench_models() {
        let (session, cal) = match bs::session_with_calibration(&model) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let mut csv = CsvWriter::create(
            dir.join(format!("{model}.csv")),
            &["qindex", "k", "rz_sq", "accuracy"],
        )
        .unwrap();
        let mut series = Vec::new();
        let markers = ['1', '2', '3', '4', '5', '6', '7', '8', '9', 'a', 'b', 'c', 'd', 'e'];
        for layer in &cal.layers {
            let mut pts = Vec::new();
            for &(k, rz, acc) in &layer.curve.points {
                csv.row(&[layer.qindex as f64, k, rz, acc]).unwrap();
                pts.push((rz, acc));
            }
            pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            series.push(Series::new(
                layer.name.clone(),
                markers[layer.qindex % markers.len()],
                pts,
            ));
        }
        csv.flush().unwrap();
        let plot = ascii_plot(
            &format!("{model}: ‖r_Z‖² (log) vs accuracy"),
            &series,
            64,
            20,
            true,
            false,
        );
        println!("{plot}");

        let rows: Vec<Vec<String>> = cal
            .layers
            .iter()
            .map(|l| {
                vec![
                    l.name.clone(),
                    format!("{:.0}", l.s),
                    format!("{:.3e}", l.t),
                    format!("{:.3e}", l.p),
                ]
            })
            .collect();
        let table = markdown_table(
            &["layer", "s_i", "t_i", "p_i"],
            &[Align::Left, Align::Right, Align::Right, Align::Right],
            &rows,
        );
        println!("{table}");
        println!(
            "mean_r* = {:.4}, base acc = {:.4}, Δacc = {:.4}\n",
            cal.mean_rstar, cal.base_accuracy, cal.delta_acc
        );
        report.push_str(&format!(
            "## {model}\n\nmean_r* = {:.4}, Δacc = {:.4}\n\n{table}\n```\n{plot}```\n\n",
            cal.mean_rstar, cal.delta_acc
        ));
        drop(session);
    }
    report.push_str(
        "\nExpected (paper): t_i roughly constant across early layers, \
         noticeably larger for the last 1–2 layers (low-rank argument, Eq. 10).\n",
    );
    bs::write_report("fig3_robustness", &report);
}
