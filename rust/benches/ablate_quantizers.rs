//! Ablation — quantizer family at a fixed bit budget: the paper's uniform
//! midpoint quantizer vs k-means codebooks (Deep Compression) vs
//! stochastic rounding (Gupta et al. 2015), plus the entropy-coded size
//! each allocation would ship at (the Deep Compression Huffman stage).
//!
//! Shape to expect: k-means ⪅ uniform in noise (learned codebook) with
//! similar accuracy at moderate bits; stochastic rounding ~2× the noise →
//! earlier accuracy cliff; entropy coding shaves 10-30 % off Σ sᵢ·bᵢ.

use adaq::bench_support as bs;
use adaq::coordinator::Session;
use adaq::io::csv::CsvWriter;
use adaq::quant::{
    entropy_coded_bits, fake_quant, kmeans_fake_quant, stochastic_fake_quant, Allocator,
};
use adaq::report::{markdown_table, Align};
use adaq::rng::Pcg32;
use adaq::tensor::Tensor;

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let dir = bs::report_dir("ablate_quantizers");
    let mut report = String::from("# Ablation — quantizer family at equal bit budget\n\n");
    for model in bs::bench_models() {
        let (session, cal) = match bs::session_with_calibration(&model) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let stats = cal.layer_stats();
        let nwl = stats.len();
        let mut csv = CsvWriter::create(
            dir.join(format!("{model}.csv")),
            &["bits", "uniform_acc", "kmeans_acc", "stochastic_acc"],
        )
        .unwrap();
        let mut rows = Vec::new();
        for bits in [2.0f32, 3.0, 4.0, 6.0, 8.0] {
            // quantize EVERY layer host-side with each family
            let mut apply = |f: &mut dyn FnMut(&Tensor, usize) -> Tensor| -> f64 {
                let mut overrides = Vec::new();
                let mut tensors = Vec::new();
                for qi in 0..nwl {
                    let (pidx, w) = session.layer_weight(qi).unwrap();
                    tensors.push((pidx, f(w, qi)));
                }
                for (pidx, t) in &tensors {
                    overrides.push((*pidx, t));
                }
                session.eval_with_overrides(&overrides).unwrap().accuracy
            };
            let uni = apply(&mut |w, _| fake_quant(w, bits));
            let km = apply(&mut |w, qi| kmeans_fake_quant(w, bits as u32, qi as u64));
            let mut rng = Pcg32::new(42);
            let sto = apply(&mut |w, _| stochastic_fake_quant(w, bits, &mut rng));
            csv.row(&[bits as f64, uni, km, sto]).unwrap();
            rows.push(vec![
                format!("{bits}"),
                format!("{uni:.4}"),
                format!("{km:.4}"),
                format!("{sto:.4}"),
            ]);
        }
        csv.flush().unwrap();
        let table = markdown_table(
            &["bits", "uniform", "kmeans", "stochastic"],
            &[Align::Right; 4],
            &rows,
        );

        // entropy-coded size of the adaptive allocation at b1 = 8
        let alloc = Allocator::Adaptive.allocate(&stats, 8.0, &vec![true; nwl], 16.0);
        let raw_bits = alloc.size_bits(&stats);
        let mut coded = 0f64;
        for qi in 0..nwl {
            let (_, w) = session.layer_weight(qi).unwrap();
            coded += entropy_coded_bits(w, alloc.bits[qi] as f32);
        }
        let entropy_line = format!(
            "adaptive@b1=8: raw {:.1} KiB → entropy-coded {:.1} KiB ({:.1}% saved)\n",
            raw_bits / 8192.0,
            coded / 8192.0,
            (1.0 - coded / raw_bits) * 100.0
        );
        println!("\n== {model} ==\n{table}\n{entropy_line}");
        report.push_str(&format!("## {model}\n\n{table}\n{entropy_line}\n"));
    }
    bs::write_report("ablate_quantizers", &report);
}
