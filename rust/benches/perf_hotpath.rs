//! PERF — hot-path micro/macro benches (EXPERIMENTS.md §Perf, BENCH.md):
//!
//! * blocked multithreaded GEMM vs the seed's naive ikj loop at
//!   512×512×512 (the headline: the calibration hot path is GEMM-bound);
//! * sparse-LHS skip loop vs the dense blocked kernel on post-ReLU-like
//!   activations (is the `av == 0` branch ever worth it?);
//! * CPU backend full-dataset evaluation scaling across worker threads
//!   (a procedurally generated CNN — no artifacts needed);
//! * host-side quantizer throughput (GB/s) and allocator latency;
//! * per-model session forward paths when artifacts are present.
//!
//! `--json` additionally writes `BENCH_hotpath.json` so the perf
//! trajectory can be tracked across PRs (schema in BENCH.md).
//!
//! `ADAQ_BENCH_TINY=1` shrinks every problem size (~10× faster end to
//! end) while keeping **every JSON row present** — the CI bench-smoke
//! job runs this mode and fails if a documented row goes missing.
//! Timings from tiny runs are smoke signals, not perf trajectory points.

use adaq::bench_support as bs;
use adaq::coordinator::{
    run_degrade, run_open_loop, run_rate_ladder, run_scenario, run_server, run_sweep_jobs,
    ArrivalKind, DegradeConfig, EvalCache, FaultPlan, OpenLoopConfig, Rung, ScenarioSpec,
    ServerConfig, Session, ShedPolicy, SweepConfig, TenantSpec,
};
use adaq::dataset::Dataset;
use adaq::io::Json;
use adaq::measure::{calibrate_model_jobs, SearchParams};
use adaq::model::{Manifest, ModelArtifacts, WeightStore};
use adaq::nn::GraphExecutor;
use adaq::quant::{fake_quant_into, Allocator, LayerStats, QuantRange};
use adaq::report::{markdown_table, Align};
use adaq::rng::{fill_normal, Pcg32};
use adaq::runtime::{Backend, CpuBackend};
use adaq::tensor::{
    active_kernel, gemm_i8_packed, gemm_i8_packed_with_kernel, matmul_into_with_kernel,
    matmul_reference, matmul_sparse_lhs, matmul_threaded, pack_i8, Tensor,
};
use adaq::util::{Scratch, Timer};

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t = Timer::start();
    for _ in 0..n {
        f();
    }
    t.seconds() / n as f64
}

fn randn_tensor(shape: &[usize], rng: &mut Pcg32) -> Tensor {
    let n: usize = shape.iter().product();
    let mut data = vec![0f32; n];
    fill_normal(rng, &mut data);
    Tensor::from_vec(shape, data).unwrap()
}

/// A small procedural CNN over the shapes dataset — lets the eval-scaling
/// bench run on a fresh checkout with no artifacts.
fn demo_manifest() -> Manifest {
    Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "bench_cnn", "input_shape": [16,16,1], "num_classes": 10,
        "output": "fc", "num_weighted_layers": 3,
        "total_quantizable_params": 1384,
        "layers": [
          {"name":"conv1","kind":"conv","inputs":["input"],"cin":1,"cout":8,
           "k":3,"stride":1,"pad":1,"param_idx_w":1,"param_idx_b":2,
           "qindex":0,"s_i":72},
          {"name":"relu1","kind":"relu","inputs":["conv1"]},
          {"name":"pool1","kind":"maxpool","inputs":["relu1"],"k":2,
           "stride":2,"pad":0},
          {"name":"conv2","kind":"conv","inputs":["pool1"],"cin":8,
           "cout":16,"k":3,"stride":1,"pad":1,"param_idx_w":3,
           "param_idx_b":4,"qindex":1,"s_i":1152},
          {"name":"relu2","kind":"relu","inputs":["conv2"]},
          {"name":"gap","kind":"gap","inputs":["relu2"]},
          {"name":"fc","kind":"dense","inputs":["gap"],"cin":16,"cout":10,
           "param_idx_w":5,"param_idx_b":6,"qindex":2,"s_i":160}
        ]}"#,
        )
        .unwrap(),
    )
    .unwrap()
}

fn demo_params(rng: &mut Pcg32) -> Vec<Tensor> {
    vec![
        randn_tensor(&[3, 3, 1, 8], rng),
        randn_tensor(&[8], rng),
        randn_tensor(&[3, 3, 8, 16], rng),
        randn_tensor(&[16], rng),
        randn_tensor(&[16, 10], rng),
        randn_tensor(&[10], rng),
    ]
}

/// In-memory artifacts for the demo CNN (weights drawn from `seed`) —
/// one construction shared by the coordinator-tier and serve-engine
/// sections so their model stays identical by construction.
fn demo_artifacts(seed: u64) -> ModelArtifacts {
    let mut rng = Pcg32::new(seed);
    let params = demo_params(&mut rng);
    let named: Vec<(String, Tensor)> =
        ["conv1.w", "conv1.b", "conv2.w", "conv2.b", "fc.w", "fc.b"]
            .iter()
            .map(|s| s.to_string())
            .zip(params)
            .collect();
    ModelArtifacts {
        dir: std::path::PathBuf::from("<bench>"),
        manifest: demo_manifest(),
        weights: WeightStore::from_params(named),
    }
}

/// Smoke-size mode for CI (`ADAQ_BENCH_TINY=1`): every section runs,
/// every JSON row is emitted, problem sizes shrink.
fn tiny() -> bool {
    std::env::var("ADAQ_BENCH_TINY").map_or(false, |v| !v.is_empty() && v != "0")
}

fn main() {
    let write_json = std::env::args().any(|a| a == "--json");
    let mut rows = Vec::new();
    let mut json_fields: Vec<(&str, Json)> = Vec::new();

    // ---- GEMM: seed ikj vs blocked, 512x512x512 ----
    let gemm_json;
    {
        let mut rng = Pcg32::new(7);
        let dim = if tiny() { 96usize } else { 512usize };
        let a = randn_tensor(&[dim, dim], &mut rng);
        let b = randn_tensor(&[dim, dim], &mut rng);
        let seed_s = time_n(3, || {
            let _ = matmul_reference(&a, &b).unwrap();
        });
        // forced-scalar single-thread: the dispatch-independent baseline
        // the SIMD kernel speedup is measured against
        let mut sc_out = vec![0f32; dim * dim];
        let scalar_s = time_n(3, || {
            sc_out.fill(0.0);
            matmul_into_with_kernel("scalar", a.data(), b.data(), dim, dim, dim, &mut sc_out, 1)
                .unwrap();
        });
        let one_s = time_n(3, || {
            let _ = matmul_threaded(&a, &b, 1).unwrap();
        });
        let mt_s = time_n(5, || {
            let _ = matmul_threaded(&a, &b, 0).unwrap();
        });
        let gflops = |s: f64| 2.0 * (dim * dim * dim) as f64 / s / 1e9;
        let threads = std::thread::available_parallelism().map_or(1, |v| v.get());
        let kernel = active_kernel();
        rows.push(vec![
            format!("GEMM {dim}³ seed ikj loop"),
            format!("{:.1} ms", seed_s * 1e3),
            format!("{:.2} GFLOP/s", gflops(seed_s)),
        ]);
        rows.push(vec![
            format!("GEMM {dim}³ scalar kernel 1 thread"),
            format!("{:.1} ms", scalar_s * 1e3),
            format!("{:.2} GFLOP/s — {:.2}x vs seed", gflops(scalar_s), seed_s / scalar_s),
        ]);
        rows.push(vec![
            format!("GEMM {dim}³ {kernel} kernel 1 thread"),
            format!("{:.1} ms", one_s * 1e3),
            format!("{:.2} GFLOP/s — {:.2}x vs scalar", gflops(one_s), scalar_s / one_s),
        ]);
        rows.push(vec![
            format!("GEMM {dim}³ {kernel} kernel {threads} threads"),
            format!("{:.1} ms", mt_s * 1e3),
            format!("{:.2} GFLOP/s — {:.2}x vs seed", gflops(mt_s), seed_s / mt_s),
        ]);
        gemm_json = Json::obj(vec![
            ("m", Json::Num(dim as f64)),
            ("n", Json::Num(dim as f64)),
            ("k", Json::Num(dim as f64)),
            ("kernel", Json::Str(kernel.to_string())),
            ("seed_ikj_ms", Json::Num(seed_s * 1e3)),
            ("scalar_1t_ms", Json::Num(scalar_s * 1e3)),
            ("blocked_1t_ms", Json::Num(one_s * 1e3)),
            ("blocked_mt_ms", Json::Num(mt_s * 1e3)),
            ("threads", Json::Num(threads as f64)),
            ("speedup_1t", Json::Num(seed_s / one_s)),
            ("speedup_mt", Json::Num(seed_s / mt_s)),
            ("speedup_1t_vs_scalar", Json::Num(scalar_s / one_s)),
        ]);
    }
    json_fields.push(("gemm_512", gemm_json));

    // ---- int8 GEMM 512³: the integer serving kernel ----
    {
        let dim = if tiny() { 96usize } else { 512usize };
        let mut rng = Pcg32::new(17);
        let a: Vec<i8> = (0..dim * dim).map(|_| (rng.next_u32() >> 24) as u8 as i8).collect();
        let b: Vec<i8> = (0..dim * dim).map(|_| (rng.next_u32() >> 24) as u8 as i8).collect();
        // weights are packed once per bit-vector on the serve path, so
        // measure the steady-state (pre-packed) kernel
        let packed = pack_i8(&b, dim, dim);
        let mut out = vec![0i32; dim * dim];
        let scalar_s = time_n(3, || {
            gemm_i8_packed_with_kernel("scalar", &a, &packed, dim, &mut out, 1).unwrap()
        });
        let one_s = time_n(3, || gemm_i8_packed(&a, &packed, dim, &mut out, 1));
        let mt_s = time_n(5, || gemm_i8_packed(&a, &packed, dim, &mut out, 0));
        let gops = |s: f64| 2.0 * (dim * dim * dim) as f64 / s / 1e9;
        let threads = std::thread::available_parallelism().map_or(1, |v| v.get());
        let kernel = active_kernel();
        rows.push(vec![
            format!("int8 GEMM {dim}³ scalar kernel 1 thread"),
            format!("{:.1} ms", scalar_s * 1e3),
            format!("{:.2} GOP/s", gops(scalar_s)),
        ]);
        rows.push(vec![
            format!("int8 GEMM {dim}³ {kernel} kernel 1 thread"),
            format!("{:.1} ms", one_s * 1e3),
            format!("{:.2} GOP/s — {:.2}x vs scalar", gops(one_s), scalar_s / one_s),
        ]);
        rows.push(vec![
            format!("int8 GEMM {dim}³ {kernel} kernel {threads} threads"),
            format!("{:.1} ms", mt_s * 1e3),
            format!("{:.2} GOP/s", gops(mt_s)),
        ]);
        json_fields.push((
            "gemm_512_int8",
            Json::obj(vec![
                ("dim", Json::Num(dim as f64)),
                ("kernel", Json::Str(kernel.to_string())),
                ("scalar_1t_ms", Json::Num(scalar_s * 1e3)),
                ("packed_1t_ms", Json::Num(one_s * 1e3)),
                ("packed_mt_ms", Json::Num(mt_s * 1e3)),
                ("gops_mt", Json::Num(gops(mt_s))),
                ("threads", Json::Num(threads as f64)),
                ("speedup_1t_vs_scalar", Json::Num(scalar_s / one_s)),
            ]),
        ));
    }

    // ---- sparse-LHS skip loop vs dense blocked kernel ----
    {
        let mut rng = Pcg32::new(11);
        let (m, k, n) =
            if tiny() { (192usize, 96usize, 64usize) } else { (1024usize, 512usize, 256usize) };
        let mut a = randn_tensor(&[m, k], &mut rng);
        // post-ReLU-like activations: clamp negatives to zero (~50% sparse)
        for v in a.data_mut().iter_mut() {
            *v = v.max(0.0);
        }
        let b = randn_tensor(&[k, n], &mut rng);
        let zeros = a.data().iter().filter(|&&v| v == 0.0).count();
        let sparsity = zeros as f64 / a.len() as f64;
        let sparse_s = time_n(3, || {
            let _ = matmul_sparse_lhs(&a, &b).unwrap();
        });
        let dense_s = time_n(3, || {
            let _ = matmul_threaded(&a, &b, 1).unwrap();
        });
        rows.push(vec![
            format!("sparse-LHS skip loop ({:.0}% zeros)", sparsity * 100.0),
            format!("{:.1} ms", sparse_s * 1e3),
            format!("blocked dense 1t: {:.1} ms ({:.2}x)", dense_s * 1e3, sparse_s / dense_s),
        ]);
        json_fields.push((
            "sparse_lhs",
            Json::obj(vec![
                ("sparsity", Json::Num(sparsity)),
                ("sparse_ms", Json::Num(sparse_s * 1e3)),
                ("blocked_1t_ms", Json::Num(dense_s * 1e3)),
            ]),
        ));
    }

    // ---- CPU backend full-dataset evaluation scaling ----
    {
        let mut rng = Pcg32::new(13);
        let params = demo_params(&mut rng);
        let ds = Dataset::generate(if tiny() { 320 } else { 1000 }, 20260731);
        let batch = if tiny() { 40 } else { 125 };
        let batches: Vec<Tensor> = ds
            .batches(batch)
            .into_iter()
            .map(|(s, l)| ds.batch(s, l).unwrap())
            .collect();
        let n_imgs = batches.len() * batch;
        let avail = std::thread::available_parallelism().map_or(1, |v| v.get());
        let mut scaling = Vec::new();
        let mut base_s = 0.0;
        for threads in [1usize, 2, 4, 8] {
            if threads > avail.max(1) * 2 {
                break;
            }
            let be = CpuBackend::new(demo_manifest(), params.clone(), batches.clone())
                .unwrap()
                .with_threads(threads);
            // pin nested GEMMs on the 1-worker run (which executes on this
            // thread) so the scaling baseline is truly single-threaded;
            // multi-worker runs pin their own workers internally
            if threads == 1 {
                adaq::tensor::set_gemm_threads(1);
            }
            let per = time_n(3, || {
                let _ = be.forward_all(&[]).unwrap();
            });
            if threads == 1 {
                adaq::tensor::set_gemm_threads(0);
                base_s = per;
            }
            rows.push(vec![
                format!("cpu eval {n_imgs} imgs, {threads} worker(s)"),
                format!("{:.1} ms/dataset", per * 1e3),
                format!("{:.0} img/s — {:.2}x vs 1 worker", n_imgs as f64 / per, base_s / per),
            ]);
            scaling.push(Json::obj(vec![
                ("threads", Json::Num(threads as f64)),
                ("ms_per_dataset", Json::Num(per * 1e3)),
                ("imgs_per_s", Json::Num(n_imgs as f64 / per)),
                ("speedup_vs_1t", Json::Num(base_s / per)),
            ]));
        }
        json_fields.push(("eval_scaling", Json::Arr(scaling)));
    }

    // ---- coordinator tier: calibration + sweep wall time, 1 job vs a
    //      full pool (outputs are byte-identical; only wall time moves) ----
    {
        let artifacts = demo_artifacts(23);
        let test = Dataset::generate(if tiny() { 200 } else { 500 }, 20260731);
        let session =
            Session::from_parts(artifacts, test, if tiny() { 50 } else { 125 }).unwrap();
        let delta = session.baseline().accuracy * 0.5;
        let sp = SearchParams { max_iters: 10, seeds: 1, ..Default::default() };
        let jobs = std::thread::available_parallelism().map_or(1, |v| v.get()).min(16);

        let calib = |j: usize| calibrate_model_jobs(&session, delta, &sp, j, |_| {}).unwrap();
        let t = Timer::start();
        let cal = calib(1);
        let calib_1 = t.seconds();
        let t = Timer::start();
        let cal_n = calib(jobs);
        let calib_n = t.seconds();
        assert_eq!(cal.layers.len(), cal_n.layers.len());
        rows.push(vec![
            "calibrate (3 layers, 1 job)".into(),
            format!("{:.0} ms", calib_1 * 1e3),
            "Alg. 1+2 wall time, sequential".into(),
        ]);
        rows.push(vec![
            format!("calibrate (3 layers, {jobs} jobs)"),
            format!("{:.0} ms", calib_n * 1e3),
            format!("{:.2}x vs 1 job — byte-identical output", calib_1 / calib_n),
        ]);
        json_fields.push((
            "calib_wall",
            Json::obj(vec![
                ("layers", Json::Num(cal.layers.len() as f64)),
                ("jobs1_ms", Json::Num(calib_1 * 1e3)),
                ("jobsN_ms", Json::Num(calib_n * 1e3)),
                ("jobs", Json::Num(jobs as f64)),
                ("speedup", Json::Num(calib_1 / calib_n)),
            ]),
        ));

        let stats = cal.layer_stats();
        let cfg = SweepConfig::default_for(stats.len());
        let sweep = |j: usize, cache: &EvalCache| {
            run_sweep_jobs(&session, Allocator::Adaptive, &stats, &cfg, j, cache).unwrap()
        };
        let t = Timer::start();
        let r1 = sweep(1, &EvalCache::new());
        let sweep_1 = t.seconds();
        let shared = EvalCache::new();
        let t = Timer::start();
        let rn = sweep(jobs, &shared);
        let sweep_n = t.seconds();
        let unique = shared.len();
        // a second sweep over the warm cache re-evaluates nothing
        let t = Timer::start();
        let _ = sweep(jobs, &shared);
        let sweep_hot = t.seconds();
        assert_eq!(r1.points.len(), rn.points.len());
        rows.push(vec![
            format!("sweep adaptive ({} pts, 1 job)", r1.points.len()),
            format!("{:.0} ms", sweep_1 * 1e3),
            format!("{unique} unique allocations evaluated"),
        ]);
        rows.push(vec![
            format!("sweep adaptive ({} pts, {jobs} jobs)", rn.points.len()),
            format!("{:.0} ms", sweep_n * 1e3),
            format!(
                "{:.2}x vs 1 job; warm cache re-run {:.1} ms",
                sweep_1 / sweep_n,
                sweep_hot * 1e3
            ),
        ]);
        json_fields.push((
            "sweep_wall",
            Json::obj(vec![
                ("points", Json::Num(r1.points.len() as f64)),
                ("unique_evals", Json::Num(unique as f64)),
                ("jobs1_ms", Json::Num(sweep_1 * 1e3)),
                ("jobsN_ms", Json::Num(sweep_n * 1e3)),
                ("warm_cache_ms", Json::Num(sweep_hot * 1e3)),
                ("jobs", Json::Num(jobs as f64)),
                ("speedup", Json::Num(sweep_1 / sweep_n)),
            ]),
        ));
    }

    // ---- batch-1 serving: cached GraphPlan vs per-request rebuild ----
    {
        let mut rng = Pcg32::new(19);
        let params = demo_params(&mut rng);
        let ds = Dataset::generate(64, 20260731);
        let x = ds.batch(0, 1).unwrap();
        let bits = vec![8.0f32; 3];
        let manifest = demo_manifest();

        // PR-1 behavior: the executor analysis (use counts, fusion
        // tables) was rebuilt per request; quantized params were cached.
        let qparams: Vec<Tensor> =
            params.iter().map(|p| adaq::quant::fake_quant(p, 8.0)).collect();
        let qrefs: Vec<&Tensor> = qparams.iter().collect();
        let reps = if tiny() { 150 } else { 500 };
        let mut scratch = Scratch::new();
        let rebuild_s = time_n(reps, || {
            let exec = GraphExecutor::new(&manifest);
            let _ = exec.forward_with(&x, &qrefs, &mut scratch).unwrap();
        });

        // PR 2+: the plan is computed once in CpuBackend::new
        let be = CpuBackend::new(demo_manifest(), params.clone(), vec![x.clone()]).unwrap();
        let cached_s = time_n(reps, || {
            let _ = be.qforward_one(&x, &bits).unwrap();
        });

        // and the integer path on top of the cached plan
        let be8 = CpuBackend::new(demo_manifest(), params.clone(), vec![x.clone()])
            .unwrap()
            .with_int8_serving(true);
        let int8_s = time_n(reps, || {
            let _ = be8.qforward_one(&x, &bits).unwrap();
        });

        rows.push(vec![
            "serve b1 rebuild/request (PR1)".into(),
            format!("{:.3} ms", rebuild_s * 1e3),
            "GraphExecutor analysis rebuilt per request".into(),
        ]);
        rows.push(vec![
            "serve b1 cached GraphPlan".into(),
            format!("{:.3} ms", cached_s * 1e3),
            format!("{:.2}x vs rebuild", rebuild_s / cached_s),
        ]);
        rows.push(vec![
            "serve b1 int8 path".into(),
            format!("{:.3} ms", int8_s * 1e3),
            format!("{:.2}x vs rebuild", rebuild_s / int8_s),
        ]);
        json_fields.push((
            "serve_batch1",
            Json::obj(vec![
                ("rebuild_ms", Json::Num(rebuild_s * 1e3)),
                ("cached_plan_ms", Json::Num(cached_s * 1e3)),
                ("int8_ms", Json::Num(int8_s * 1e3)),
            ]),
        ));
    }

    // ---- concurrent serve engine: workers × deadline micro-batching.
    //      Accuracy/predictions are invariant across configs (asserted);
    //      only throughput and latency move. ----
    // measured closed-loop w1 b1 service rate — the open-loop section
    // below uses it as its admission-controller drain capacity so the
    // rate ladder lands around the knee on any machine
    let closed_rps_est: f64;
    {
        let test = Dataset::generate(if tiny() { 128 } else { 512 }, 20260731);
        let session = Session::from_parts(demo_artifacts(29), test.clone(), 1).unwrap();
        let bits = vec![8.0f32; 3];
        let n = if tiny() { 300 } else { 2000 };
        let avail = std::thread::available_parallelism().map_or(1, |v| v.get()).min(16);
        let w = avail.clamp(2, 8);
        let mut serve_json = Vec::new();
        let mut base_correct: Option<usize> = None;
        let mut base_rps = 0.0f64;
        for (workers, batch, deadline_us) in
            [(1usize, 1usize, 0u64), (w, 1, 0), (w, 4, 200), (w, 8, 200)]
        {
            let cfg = ServerConfig {
                workers,
                batch,
                deadline_us,
                queue_cap: 0,
                fault: FaultPlan::default(),
            };
            let r = run_server(&session, &test, &bits, n, &cfg).unwrap();
            match base_correct {
                None => {
                    base_correct = Some(r.correct);
                    base_rps = r.throughput_rps;
                }
                Some(c) => assert_eq!(
                    c, r.correct,
                    "serve correctness must be invariant across engine configs"
                ),
            }
            rows.push(vec![
                format!("serve_mt {n} reqs, w{workers} b{batch} d{deadline_us}µs"),
                format!("{:.0} req/s", r.throughput_rps),
                format!(
                    "{:.2}x vs w1 b1; mean batch {:.2}; sojourn p50/p99/p99.9 \
                     {:.2}/{:.2}/{:.2} ms",
                    if base_rps > 0.0 { r.throughput_rps / base_rps } else { 0.0 },
                    r.mean_batch_occupancy(),
                    r.p50_ms,
                    r.p99_ms,
                    r.p999_ms
                ),
            ]);
            serve_json.push(Json::obj(vec![
                ("workers", Json::Num(workers as f64)),
                ("batch", Json::Num(batch as f64)),
                ("deadline_us", Json::Num(deadline_us as f64)),
                ("requests", Json::Num(n as f64)),
                ("rps", Json::Num(r.throughput_rps)),
                ("speedup_vs_seq", Json::Num(if base_rps > 0.0 {
                    r.throughput_rps / base_rps
                } else {
                    0.0
                })),
                ("p50_ms", Json::Num(r.p50_ms)),
                ("p99_ms", Json::Num(r.p99_ms)),
                ("p999_ms", Json::Num(r.p999_ms)),
                ("service_p50_ms", Json::Num(r.service_p50_ms)),
                ("service_p999_ms", Json::Num(r.service_p999_ms)),
                ("mean_batch", Json::Num(r.mean_batch_occupancy())),
                ("forwards", Json::Num(r.forwards as f64)),
                ("correct", Json::Num(r.correct as f64)),
            ]));
        }
        // the integer path through the same engine and the same model
        // (one config is enough for the trajectory; invariance is
        // covered by tests/serve_mt.rs)
        let i8_session = Session::from_parts_int8(demo_artifacts(29), test.clone(), 1).unwrap();
        let cfg = ServerConfig {
            workers: w,
            batch: 4,
            deadline_us: 200,
            queue_cap: 0,
            fault: FaultPlan::default(),
        };
        let r = run_server(&i8_session, &test, &bits, n, &cfg).unwrap();
        rows.push(vec![
            format!("serve_mt {n} reqs, w{w} b4 int8"),
            format!("{:.0} req/s", r.throughput_rps),
            format!(
                "integer path; mean batch {:.2}; sojourn p50 {:.2} ms",
                r.mean_batch_occupancy(),
                r.p50_ms
            ),
        ]);
        serve_json.push(Json::obj(vec![
            ("workers", Json::Num(w as f64)),
            ("batch", Json::Num(4.0)),
            ("deadline_us", Json::Num(200.0)),
            ("int8", Json::Bool(true)),
            ("requests", Json::Num(n as f64)),
            ("rps", Json::Num(r.throughput_rps)),
            ("p50_ms", Json::Num(r.p50_ms)),
            ("p99_ms", Json::Num(r.p99_ms)),
            ("mean_batch", Json::Num(r.mean_batch_occupancy())),
            ("correct", Json::Num(r.correct as f64)),
        ]));
        json_fields.push(("serve_mt", Json::Arr(serve_json)));
        closed_rps_est = base_rps;
    }

    // ---- observability overhead: the same serve config with the flight
    //      recorder + metrics hub on (the default) vs globally disabled.
    //      The recorder is always-on in production, so this row IS the
    //      perf trajectory guard: BENCH.md documents a ≤3% budget. ----
    {
        let test = Dataset::generate(if tiny() { 128 } else { 512 }, 20260731);
        let session = Session::from_parts(demo_artifacts(29), test.clone(), 1).unwrap();
        let bits = vec![8.0f32; 3];
        let n = if tiny() { 300 } else { 2000 };
        let avail = std::thread::available_parallelism().map_or(1, |v| v.get()).min(16);
        let w = avail.clamp(2, 8);
        let cfg = ServerConfig {
            workers: w,
            batch: 4,
            deadline_us: 200,
            queue_cap: 0,
            fault: FaultPlan::default(),
        };
        let run = || {
            let t = Timer::start();
            let r = run_server(&session, &test, &bits, n, &cfg).unwrap();
            (r, t.seconds())
        };
        let _ = run(); // warm the quantized-parameter cache
        let (r_on, s_on) = run();
        let (r_off, s_off) = bs::with_obs_disabled(&run);
        assert_eq!(r_on.correct, r_off.correct, "obs must not change predictions");
        let rps_on = n as f64 / s_on;
        let rps_off = n as f64 / s_off;
        let overhead_pct = (s_on / s_off - 1.0) * 100.0;
        rows.push(vec![
            format!("obs_overhead serve {n} reqs, w{w} b4"),
            format!("{overhead_pct:+.1}%"),
            format!(
                "{rps_on:.0} rps on vs {rps_off:.0} rps off; {} events recorded",
                r_on.telemetry.events.len()
            ),
        ]);
        json_fields.push((
            "obs_overhead",
            Json::obj(vec![
                ("requests", Json::Num(n as f64)),
                ("workers", Json::Num(w as f64)),
                ("rps_on", Json::Num(rps_on)),
                ("rps_off", Json::Num(rps_off)),
                ("overhead_pct", Json::Num(overhead_pct)),
                ("events", Json::Num(r_on.telemetry.events.len() as f64)),
            ]),
        ));
    }

    // ---- open-loop serve: offered-rate ladder with deterministic
    //      admission control. Shed accounting must close exactly
    //      (asserted); the shed set is a pure function of the seed and
    //      the admission model, never of worker count or timing. ----
    {
        let test = Dataset::generate(if tiny() { 128 } else { 512 }, 20260731);
        let session = Session::from_parts(demo_artifacts(29), test.clone(), 1).unwrap();
        let bits = vec![8.0f32; 3];
        let n = if tiny() { 200 } else { 1200 };
        let avail = std::thread::available_parallelism().map_or(1, |v| v.get()).min(16);
        let w = avail.clamp(2, 8);
        let cfg = ServerConfig {
            workers: w,
            batch: 4,
            deadline_us: 200,
            queue_cap: 0,
            fault: FaultPlan::default(),
        };
        // admission capacity = the measured closed-loop service rate
        // (pin a floor in case the serve_mt clock degenerated)
        let drain = if closed_rps_est > 1.0 { closed_rps_est } else { 500.0 };
        let base = OpenLoopConfig {
            rate_rps: drain,
            drain_rps: drain,
            requests: n,
            seed: 42,
            shed: ShedPolicy::RejectNew,
            slice_ms: 0,
            live_shed: false,
        };
        let rates = [drain * 0.7, drain * 1.5, drain * 3.0];
        let curve = run_rate_ladder(&session, &test, &bits, &cfg, &base, &rates).unwrap();
        let mut ol_json = Vec::new();
        fn push_point(
            r: &adaq::coordinator::OpenLoopReport,
            w: usize,
            rows: &mut Vec<Vec<String>>,
            ol_json: &mut Vec<Json>,
        ) {
            assert_eq!(
                r.accepted + r.shed_total(),
                r.offered,
                "open-loop shed accounting must close"
            );
            rows.push(vec![
                format!(
                    "serve_openloop {:.0} rps offered, w{w} b4 [{}]",
                    r.offered_rate_rps,
                    r.shed_policy.name()
                ),
                format!("{:.0} rps goodput", r.goodput_rps),
                format!(
                    "{}/{} accepted, {} shed; sojourn p50/p99 {:.2}/{:.2} ms; mean depth {:.1}",
                    r.accepted,
                    r.offered,
                    r.shed_total(),
                    r.serve.p50_ms,
                    r.serve.p99_ms,
                    r.mean_depth
                ),
            ]);
            ol_json.push(r.to_json());
        }
        for r in &curve.points {
            push_point(r, w, &mut rows, &mut ol_json);
        }
        // one oldest-drop rung at the deepest overload for the trajectory
        let ol = OpenLoopConfig { rate_rps: drain * 3.0, shed: ShedPolicy::DropOldest, ..base };
        let r = run_open_loop(&session, &test, &bits, &cfg, &ol).unwrap();
        push_point(&r, w, &mut rows, &mut ol_json);
        json_fields.push(("serve_openloop", Json::Arr(ol_json)));

        // ---- degradation controller vs pure shedding at 3x capacity:
        //      the graceful-degradation headline. Same arrival stream,
        //      same rung-0 capacity — the controller must retain
        //      strictly more goodput than the reject ledger (asserted;
        //      the ledger-level claim is machine-independent). ----
        let rate = drain * 3.0;
        // slice the virtual run into ~12 controller decision points so
        // the ladder walk happens at any machine speed (the CLI default
        // of 20 ms is the ceiling)
        let slice_ms = ((n as f64 / rate * 1000.0) / 12.0).clamp(1.0, 20.0) as u64;
        let cache = EvalCache::new();
        let ladder = vec![
            Rung::calibrated(&session, &cache, "b8", vec![8.0; 3], drain).unwrap(),
            Rung::calibrated(&session, &cache, "b6", vec![6.0; 3], drain * 1.5).unwrap(),
            Rung::calibrated(&session, &cache, "b4", vec![4.0; 3], drain * 2.25).unwrap(),
        ];
        let dc = DegradeConfig::new(ladder);
        let ol = OpenLoopConfig {
            rate_rps: rate,
            shed: ShedPolicy::RejectNew,
            slice_ms,
            ..base
        };
        let deg = run_degrade(&session, &test, &cfg, &ol, &dc).unwrap();
        let rej = run_open_loop(&session, &test, &bits, &cfg, &ol).unwrap();
        assert_eq!(
            deg.open.accepted + deg.open.shed_total() + deg.open.live_shed + deg.open.errored,
            deg.open.offered,
            "degrade accounting must close exactly"
        );
        assert!(!deg.switches.is_empty(), "3x overload must walk down the ladder");
        assert!(
            deg.open.accepted > rej.accepted,
            "degrade must beat pure shedding at 3x capacity: {} vs {} accepted",
            deg.open.accepted,
            rej.accepted
        );
        rows.push(vec![
            format!("serve_degrade {rate:.0} rps offered, 3-rung ladder, w{w}"),
            format!("{:.0} rps goodput", deg.open.goodput_rps),
            format!(
                "{}/{} accepted ({} switches, est acc {:.4}) vs reject {}/{}",
                deg.open.accepted,
                deg.open.offered,
                deg.switches.len(),
                deg.est_accuracy,
                rej.accepted,
                rej.offered
            ),
        ]);
        json_fields.push((
            "serve_degrade",
            Json::obj(vec![
                ("degrade", deg.to_json()),
                (
                    "reject_baseline",
                    Json::obj(vec![
                        ("accepted", Json::Num(rej.accepted as f64)),
                        ("shed", Json::Num(rej.shed_total() as f64)),
                        ("goodput_rps", Json::Num(rej.goodput_rps)),
                    ]),
                ),
                ("slice_ms", Json::Num(slice_ms as f64)),
            ]),
        ));

        // ---- scenario engine: a 3-tenant mix (two steady Poisson
        //      streams + one MMPP burster) against the measured drain
        //      rate. Per-tenant accounting must close exactly, the
        //      bursts must show up as shed-heavy slices next to clean
        //      ones, and weighted admission must protect the heavy
        //      interactive tenant (all asserted — ledger-level claims,
        //      machine-independent). ----
        let nt = n / 3;
        let slice_ms = ((nt as f64 / (0.25 * drain) * 1000.0) / 12.0).clamp(1.0, 20.0) as u64;
        let spec = ScenarioSpec {
            name: "bench_mix".into(),
            tenants: vec![
                TenantSpec {
                    weight: 4.0,
                    slo_ms: 50.0,
                    ..TenantSpec::poisson("interactive", drain * 0.25, nt)
                },
                TenantSpec {
                    bits: Some(vec![4.0; 3]),
                    ..TenantSpec::poisson("batch", drain * 0.25, nt)
                },
                TenantSpec {
                    weight: 2.0,
                    arrivals: ArrivalKind::Mmpp {
                        rate_hi_rps: drain * 3.0,
                        rate_lo_rps: drain * 0.1,
                        mean_hi_ms: 40.0,
                        mean_lo_ms: 120.0,
                    },
                    ..TenantSpec::poisson("burst", drain, nt)
                },
            ],
            drain_rps: drain,
            queue_cap: 12,
            seed: 42,
            slice_ms,
            shed: ShedPolicy::RejectNew,
        };
        let r = run_scenario(&session, &test, &bits, &cfg, &spec, None, false).unwrap();
        assert_eq!(
            r.open.accepted + r.open.shed_total() + r.open.live_shed + r.open.errored,
            r.open.offered,
            "scenario accounting must close in total"
        );
        for t in &r.tenants {
            assert!(t.closes(), "tenant {} accounting must close", t.name);
        }
        assert!(r.open.shed_total() > 0, "the burst tenant must overload the queue");
        let sheddy = r
            .plan_slices
            .iter()
            .filter(|s| s.shed.iter().sum::<usize>() > 0)
            .count();
        let clean = r
            .plan_slices
            .iter()
            .filter(|s| s.offered.iter().sum::<usize>() > 0 && s.shed.iter().sum::<usize>() == 0)
            .count();
        assert!(
            sheddy > 0 && clean > 0,
            "bursty shedding must be slice-local: {sheddy} shed-heavy vs {clean} clean slices"
        );
        let frac = |t: &adaq::coordinator::server::TenantReport| {
            t.shed_total() as f64 / t.offered.max(1) as f64
        };
        assert!(
            frac(&r.tenants[1]) >= frac(&r.tenants[0]),
            "weighted admission must not shed the heavy tenant harder than the light one"
        );
        rows.push(vec![
            format!("serve_scenario 3-tenant mix, w{w} [{}]", spec.shed.name()),
            format!("{:.0} rps goodput", r.open.goodput_rps),
            format!(
                "{}/{} accepted, {} shed; tenant shed% {:.0}/{:.0}/{:.0}; {} slices",
                r.open.accepted,
                r.open.offered,
                r.open.shed_total(),
                frac(&r.tenants[0]) * 100.0,
                frac(&r.tenants[1]) * 100.0,
                frac(&r.tenants[2]) * 100.0,
                r.plan_slices.len()
            ),
        ]);
        json_fields.push(("serve_scenario", r.to_json()));
    }

    // ---- host-side quantizer throughput ----
    {
        let mut rng = Pcg32::new(1);
        let elems = if tiny() { 1usize << 19 } else { 4usize << 20 };
        let mut data = vec![0f32; elems];
        fill_normal(&mut rng, &mut data);
        let t = Tensor::from_vec(&[data.len()], data).unwrap();
        let range = QuantRange::of(&t);
        let mut out = vec![0f32; t.len()];
        let per = time_n(10, || fake_quant_into(t.data(), range, 8.0, &mut out));
        let mi = elems as f64 / (1 << 20) as f64;
        rows.push(vec![
            format!("fake_quant host ({mi}Mi f32)"),
            format!("{:.2} ms", per * 1e3),
            format!("{:.2} GB/s", (t.len() * 4) as f64 / per / 1e9),
        ]);
        json_fields.push((
            "fake_quant",
            Json::obj(vec![
                ("mi_f32", Json::Num(mi)),
                ("ms", Json::Num(per * 1e3)),
                ("gbps", Json::Num((t.len() * 4) as f64 / per / 1e9)),
            ]),
        ));
    }

    // ---- allocator latency ----
    {
        let stats: Vec<LayerStats> = (0..64)
            .map(|i| LayerStats {
                name: format!("l{i}"),
                s: 1000.0 * (i + 1) as f64,
                p: 100.0 + i as f64,
                t: 1.0 + (i % 7) as f64,
            })
            .collect();
        let mask = vec![true; stats.len()];
        let per = time_n(1000, || {
            let _ = Allocator::Adaptive.allocate(&stats, 8.0, &mask, 16.0);
        });
        rows.push(vec![
            "adaptive allocate (64 layers)".into(),
            format!("{:.2} µs", per * 1e6),
            String::new(),
        ]);
        json_fields.push((
            "allocator_us",
            Json::Num(per * 1e6),
        ));
    }

    // ---- per-model session forward paths (artifacts needed) ----
    if bs::artifacts_available() {
        let root = bs::artifacts_root();
        for model in bs::bench_models() {
            let session = match adaq::coordinator::Session::open(&root, &model, bs::bench_batch()) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("skip {model}: {e}");
                    continue;
                }
            };
            let backend = session.backend_name();
            let manifest = &session.artifacts.manifest;
            let nwl = manifest.num_weighted_layers;
            let test = Dataset::load(&root, "test").unwrap();
            let n_imgs = (test.len() / session.batch_size()) * session.batch_size();

            // full-dataset fp32 forward (cached-state hot path)
            let per_fwd = time_n(3, || {
                let _ = session.eval_with_overrides(&[]).unwrap();
            });
            rows.push(vec![
                format!("{model} forward ({backend}, b{})", session.batch_size()),
                format!("{:.1} ms/dataset", per_fwd * 1e3),
                format!("{:.0} img/s", n_imgs as f64 / per_fwd),
            ]);

            // full-dataset quantized forward
            let bits = vec![8.0f32; nwl];
            let per_q = time_n(3, || {
                let _ = session.eval_qbits(&bits).unwrap();
            });
            rows.push(vec![
                format!("{model} qforward ({backend} fake-quant)"),
                format!("{:.1} ms/dataset", per_q * 1e3),
                format!("{:.2}x of fp32 fwd", per_q / per_fwd),
            ]);

            // single-thread nn baseline on one batch, scaled to dataset
            let exec = GraphExecutor::new(manifest);
            let params = session.artifacts.weights.tensors();
            let xb = test.batch(0, session.batch_size()).unwrap();
            adaq::tensor::set_gemm_threads(1);
            let per_rust_batch = time_n(2, || {
                let _ = exec.forward(&xb, &params).unwrap();
            });
            adaq::tensor::set_gemm_threads(0);
            let per_rust = per_rust_batch * (n_imgs / session.batch_size()) as f64;
            rows.push(vec![
                format!("{model} forward (nn, 1 thread)"),
                format!("{:.1} ms/dataset", per_rust * 1e3),
                format!("session path is {:.1}x faster", per_rust / per_fwd),
            ]);
        }
    }

    let table = markdown_table(
        &["path", "latency", "notes"],
        &[Align::Left, Align::Right, Align::Left],
        &rows,
    );
    println!("{table}");
    bs::write_report(
        "perf_hotpath",
        &format!("# PERF — hot-path benches\n\n{table}\n"),
    );
    if write_json {
        let j = Json::obj(json_fields);
        match j.write_file("BENCH_hotpath.json") {
            Ok(()) => eprintln!("[bench] wrote BENCH_hotpath.json"),
            Err(e) => eprintln!("[bench] cannot write BENCH_hotpath.json: {e}"),
        }
    }
}
