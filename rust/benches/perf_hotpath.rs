//! PERF — hot-path micro/macro benches (EXPERIMENTS.md §Perf):
//!
//! * PJRT forward throughput (batch 250 and 1) vs the pure-Rust `nn`
//!   substrate — the runtime must beat the CPU baseline comfortably or
//!   L3 dispatch is the bottleneck;
//! * Pallas `qforward` overhead over the plain forward (the price of
//!   on-the-fly fake-quant on the request path);
//! * host-side quantizer throughput (GB/s) and allocator latency.

use adaq::bench_support as bs;
use adaq::dataset::Dataset;
use adaq::nn::GraphExecutor;
use adaq::quant::{fake_quant_into, Allocator, LayerStats, QuantRange};
use adaq::report::{markdown_table, Align};
use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::Tensor;
use adaq::util::Timer;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t = Timer::start();
    for _ in 0..n {
        f();
    }
    t.seconds() / n as f64
}

fn main() {
    if !bs::artifacts_available() {
        return;
    }
    let root = bs::artifacts_root();
    let mut rows = Vec::new();

    // ---- host-side quantizer throughput (no artifacts needed) ----
    {
        let mut rng = Pcg32::new(1);
        let mut data = vec![0f32; 4 << 20];
        fill_normal(&mut rng, &mut data);
        let t = Tensor::from_vec(&[data.len()], data).unwrap();
        let range = QuantRange::of(&t);
        let mut out = vec![0f32; t.len()];
        let per = time_n(10, || fake_quant_into(t.data(), range, 8.0, &mut out));
        rows.push(vec![
            "fake_quant host (4Mi f32)".into(),
            format!("{:.2} ms", per * 1e3),
            format!("{:.2} GB/s", (t.len() * 4) as f64 / per / 1e9),
        ]);
    }

    // ---- allocator latency ----
    {
        let stats: Vec<LayerStats> = (0..64)
            .map(|i| LayerStats {
                name: format!("l{i}"),
                s: 1000.0 * (i + 1) as f64,
                p: 100.0 + i as f64,
                t: 1.0 + (i % 7) as f64,
            })
            .collect();
        let mask = vec![true; stats.len()];
        let per = time_n(1000, || {
            let _ = Allocator::Adaptive.allocate(&stats, 8.0, &mask, 16.0);
        });
        rows.push(vec![
            "adaptive allocate (64 layers)".into(),
            format!("{:.2} µs", per * 1e6),
            String::new(),
        ]);
    }

    // ---- per-model forward paths ----
    for model in bs::bench_models() {
        let session = match adaq::coordinator::Session::open(&root, &model, bs::bench_batch()) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skip {model}: {e}");
                continue;
            }
        };
        let manifest = &session.artifacts.manifest;
        let nwl = manifest.num_weighted_layers;
        let test = Dataset::load(&root, "test").unwrap();
        let n_imgs = (test.len() / session.batch_size()) * session.batch_size();

        // full-dataset fp32 forward (cached-buffer hot path)
        let per_fwd = time_n(3, || {
            let _ = session.eval_with_overrides(&[]).unwrap();
        });
        rows.push(vec![
            format!("{model} forward (PJRT, b{})", session.batch_size()),
            format!("{:.1} ms/dataset", per_fwd * 1e3),
            format!("{:.0} img/s", n_imgs as f64 / per_fwd),
        ]);

        // full-dataset Pallas qforward
        let bits = vec![8.0f32; nwl];
        let per_q = time_n(3, || {
            let _ = session.eval_qbits(&bits).unwrap();
        });
        rows.push(vec![
            format!("{model} qforward (Pallas fake-quant)"),
            format!("{:.1} ms/dataset", per_q * 1e3),
            format!("{:.2}x of fp32 fwd", per_q / per_fwd),
        ]);

        // pure-Rust nn baseline on one batch, scaled to dataset
        let exec = GraphExecutor::new(manifest);
        let params = session.artifacts.weights.tensors();
        let xb = test.batch(0, session.batch_size()).unwrap();
        let per_rust_batch = time_n(2, || {
            let _ = exec.forward(&xb, &params).unwrap();
        });
        let per_rust = per_rust_batch * (n_imgs / session.batch_size()) as f64;
        rows.push(vec![
            format!("{model} forward (pure-rust nn)"),
            format!("{:.1} ms/dataset", per_rust * 1e3),
            format!("PJRT is {:.1}x faster", per_rust / per_fwd),
        ]);
    }

    let table = markdown_table(
        &["path", "latency", "notes"],
        &[Align::Left, Align::Right, Align::Left],
        &rows,
    );
    println!("{table}");
    bs::write_report(
        "perf_hotpath",
        &format!("# PERF — hot-path benches\n\n{table}\n"),
    );
}
