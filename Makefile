# Repo-level targets. `verify` is the tier-1 gate every PR must keep green.

CARGO ?= cargo

.PHONY: verify build test bench doc-check fmt-check clean

verify: build test doc-check

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Hot-path benches; writes reports/perf_hotpath.md and BENCH_hotpath.json
# (see BENCH.md for how to read both).
bench:
	$(CARGO) bench --bench perf_hotpath -- --json

# Rustdoc must build clean: broken intra-doc links and malformed docs are
# errors, not warnings (the module docs double as the architecture docs —
# see ARCHITECTURE.md).
doc-check:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --quiet

fmt-check:
	$(CARGO) fmt --all --check

clean:
	$(CARGO) clean
