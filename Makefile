# Repo-level targets. `verify` is the tier-1 gate every PR must keep green.

CARGO ?= cargo

.PHONY: verify build test bench fmt-check clean

verify: build test

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Hot-path benches; writes reports/perf_hotpath.md and BENCH_hotpath.json
# (see BENCH.md for how to read both).
bench:
	$(CARGO) bench --bench perf_hotpath -- --json

fmt-check:
	$(CARGO) fmt --all --check

clean:
	$(CARGO) clean
