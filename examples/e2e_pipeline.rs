//! End-to-end validation driver (EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on all four models —
//!
//!   artifacts (L1 Pallas kernels + L2 JAX models, AOT HLO)
//!     → PJRT runtime (L3) → calibration → allocation → quantized
//!     evaluation → batch-1 quantized *serving* with latency stats,
//!
//! and cross-checks the PJRT forward against the pure-Rust `nn`
//! substrate. Prints a one-line verdict per model and a final summary.
//!
//!   cargo run --release --example e2e_pipeline

use adaq::coordinator::{serve_loop, Session};
use adaq::dataset::Dataset;
use adaq::measure::{calibrate_model, Calibration, SearchParams};
use adaq::nn::GraphExecutor;
use adaq::quant::Allocator;
use adaq::report::{markdown_table, Align};
use adaq::util::Timer;

fn main() -> adaq::Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let models = ["mini_alexnet", "mini_vgg", "mini_resnet", "mini_inception"];
    let test = Dataset::load(&root, "test")?;
    println!("test set: {} images\n", test.len());

    let mut rows = Vec::new();
    let total = Timer::start();
    for model in models {
        let t = Timer::start();
        let session = Session::open(&root, model, 250)?;
        let base = session.baseline().accuracy;

        // cross-check PJRT vs pure-rust nn (one batch)
        let exec = GraphExecutor::new(&session.artifacts.manifest);
        let params = session.artifacts.weights.tensors();
        let xb = test.batch(0, 32)?;
        let rust_logits = exec.forward(&xb, &params)?;
        let nc = session.artifacts.manifest.num_classes;
        let mut maxdiff = 0f32;
        for (i, &v) in rust_logits.data().iter().take(32 * nc).enumerate() {
            maxdiff = maxdiff.max((v - session.baseline().logits[0][i]).abs());
        }
        assert!(maxdiff < 1e-3, "{model}: PJRT vs rust-nn diverged ({maxdiff})");

        // calibrate (or reuse cache)
        let cal = match Calibration::load(&root, model) {
            Ok(c) => c,
            Err(_) => {
                let c = calibrate_model(&session, base * 0.5, &SearchParams::default(), |_| {})?;
                c.save(&root)?;
                c
            }
        };

        // allocate + evaluate at b1 = 8
        let stats = cal.layer_stats();
        let alloc = Allocator::Adaptive.allocate(&stats, 8.0, &vec![true; stats.len()], 16.0);
        let bits: Vec<f32> = alloc.bits.iter().map(|&b| b.round() as f32).collect();
        let out = session.eval_qbits(&bits)?;
        let size = alloc.size_bytes(&stats);
        let fp32 = session.artifacts.manifest.fp32_bytes();

        // batch-1 quantized serving
        let serve_session = Session::open(&root, model, 1)?;
        let stats_serve = serve_loop(&serve_session, &test, &bits, 100)?;

        rows.push(vec![
            model.to_string(),
            format!("{base:.4}"),
            format!("{:.4}", out.accuracy),
            format!("{:.2}x", fp32 / size),
            format!("{:.4}", stats_serve.accuracy()),
            format!("{:.2}", stats_serve.p50_ms),
            format!("{:.0}", stats_serve.throughput_rps),
            format!("{:.1}s", t.seconds()),
        ]);
        println!(
            "{model}: fp32 {base:.4} → int-adaptive {:.4} at {:.2}x compression, \
             serve p50 {:.2} ms [{}]",
            out.accuracy,
            fp32 / size,
            stats_serve.p50_ms,
            "OK"
        );
    }
    println!(
        "\n{}",
        markdown_table(
            &[
                "model",
                "fp32 acc",
                "adaptive@b1=8",
                "compression",
                "serve acc",
                "p50 ms",
                "req/s",
                "wall",
            ],
            &[
                Align::Left,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right
            ],
            &rows
        )
    );
    println!("e2e pipeline OK in {:.1}s", total.seconds());
    Ok(())
}
