//! Deploy-under-a-budget: the mobile scenario from the paper's
//! introduction. Given a model-size budget (KiB), find the best
//! allocation per strategy that fits, and compare the accuracy each
//! strategy can afford at that budget.
//!
//!   cargo run --release --example mobile_budget -- [model] [budget_kib]

use adaq::coordinator::{run_sweep, Session, SweepConfig};
use adaq::measure::{calibrate_model, Calibration, SearchParams};
use adaq::quant::Allocator;
use adaq::report::{markdown_table, Align};

fn main() -> adaq::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().cloned().unwrap_or_else(|| "mini_vgg".into());
    let budget_kib: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64.0);
    let root = std::path::PathBuf::from("artifacts");

    let session = Session::open(&root, &model, 250)?;
    let cal = match Calibration::load(&root, &model) {
        Ok(c) => c,
        Err(_) => {
            let c = calibrate_model(
                &session,
                session.baseline().accuracy * 0.5,
                &SearchParams::default(),
                |l| println!("{l}"),
            )?;
            c.save(&root)?;
            c
        }
    };
    let stats = cal.layer_stats();
    let manifest = &session.artifacts.manifest;
    println!(
        "{model}: fp32 {:.1} KiB, budget {budget_kib} KiB, baseline acc {:.4}\n",
        manifest.fp32_bytes() / 1024.0,
        session.baseline().accuracy
    );

    let cfg = SweepConfig::default_for(manifest.num_weighted_layers);
    let mut rows = Vec::new();
    for alloc in [Allocator::Adaptive, Allocator::Sqnr, Allocator::Equal] {
        let r = run_sweep(&session, alloc, &stats, &cfg)?;
        // best accuracy among points that fit the budget
        let best = r
            .points
            .iter()
            .filter(|p| p.size_bytes / 1024.0 <= budget_kib)
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap());
        match best {
            Some(p) => rows.push(vec![
                alloc.name().into(),
                format!("{:.1}", p.size_bytes / 1024.0),
                format!("{:.4}", p.accuracy),
                format!("{:?}", p.bits.iter().map(|&b| b as i32).collect::<Vec<_>>()),
            ]),
            None => rows.push(vec![
                alloc.name().into(),
                "-".into(),
                "does not fit".into(),
                String::new(),
            ]),
        }
    }
    println!(
        "{}",
        markdown_table(
            &["allocator", "size KiB", "best accuracy", "bits"],
            &[Align::Left, Align::Right, Align::Right, Align::Left],
            &rows
        )
    );
    Ok(())
}
