//! The paper's headline comparison (Fig. 6 / Fig. 8 protocol): trace the
//! size-accuracy frontier for the adaptive, SQNR and equal allocators on
//! one model and report the compression advantage at matched accuracy.
//!
//!   cargo run --release --example adaptive_vs_sqnr [-- <model> [--conv-only]]

use adaq::coordinator::{run_sweep, Session, SweepConfig};
use adaq::measure::{calibrate_model, Calibration, SearchParams};
use adaq::quant::Allocator;
use adaq::report::{ascii_plot, Series};

fn main() -> adaq::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "mini_alexnet".into());
    let conv_only = args.iter().any(|a| a == "--conv-only");
    let root = std::path::PathBuf::from("artifacts");

    let session = Session::open(&root, &model, 250)?;
    let cal = match Calibration::load(&root, &model) {
        Ok(c) => c,
        Err(_) => {
            let c = calibrate_model(
                &session,
                session.baseline().accuracy * 0.5,
                &SearchParams::default(),
                |l| println!("{l}"),
            )?;
            c.save(&root)?;
            c
        }
    };
    let stats = cal.layer_stats();
    let manifest = &session.artifacts.manifest;
    let cfg = if conv_only {
        SweepConfig::conv_only(manifest)
    } else {
        SweepConfig::default_for(manifest.num_weighted_layers)
    };

    let base = session.baseline().accuracy;
    let mut series = Vec::new();
    let mut at_matched: Vec<(&str, f64)> = Vec::new();
    for (alloc, marker) in [
        (Allocator::Adaptive, 'o'),
        (Allocator::Sqnr, 'x'),
        (Allocator::Equal, '+'),
    ] {
        let r = run_sweep(&session, alloc, &stats, &cfg)?;
        let hit = r.frontier.iter().find(|p| p.accuracy >= base - 0.02);
        println!("\n{} frontier:", alloc.name());
        for p in &r.frontier {
            println!("  {:>9.1} KiB  acc {:.4}", p.size_bytes / 1024.0, p.accuracy);
        }
        if let Some(p) = hit {
            at_matched.push((alloc.name(), p.size_bytes));
        }
        series.push(Series::new(
            alloc.name(),
            marker,
            r.frontier.iter().map(|p| (p.size_bytes / 1024.0, p.accuracy)).collect(),
        ));
    }
    println!(
        "\n{}",
        ascii_plot(
            &format!(
                "{model}{}: size (KiB) vs accuracy",
                if conv_only { " (conv-only)" } else { "" }
            ),
            &series,
            70,
            20,
            false,
            false
        )
    );
    let size_of = |n: &str| at_matched.iter().find(|(a, _)| *a == n).map(|(_, s)| *s);
    if let (Some(a), Some(s), Some(e)) = (size_of("adaptive"), size_of("sqnr"), size_of("equal")) {
        println!(
            "at ≤2% accuracy drop: adaptive {:.1} KiB — {:.0}% smaller than sqnr ({:.1} KiB), \
             {:.0}% smaller than equal ({:.1} KiB)",
            a / 1024.0,
            (1.0 - a / s) * 100.0,
            s / 1024.0,
            (1.0 - a / e) * 100.0,
            e / 1024.0
        );
    }
    Ok(())
}
