//! Quickstart: the complete adaptive-quantization pipeline, end to end,
//! with **zero setup** — no artifacts, no PJRT, no Python.
//!
//!   cargo run --release --example quickstart
//!
//! Everything runs on the pure-Rust [`CpuBackend`]: the example
//! procedurally generates the shapes dataset, trains a small MLP on it
//! with hand-rolled SGD (forward/backward through the same blocked GEMM
//! the coordinator uses), then runs the paper's method:
//!
//!   1. build an in-memory model + session (no files),
//!   2. calibrate per-layer robustness t_i and noise prefactor p_i
//!      (Algorithms 1 & 2),
//!   3. solve the closed-form optimal bit-widths (Eq. 22),
//!   4. evaluate the quantized model and report accuracy vs model size.
//!
//! Pass a model name to run on trained artifacts instead (requires
//! `make artifacts`):  cargo run --release --example quickstart mini_alexnet

use adaq::coordinator::Session;
use adaq::dataset::{Dataset, IMG, NUM_CLASSES, TEST_SEED, TRAIN_SEED};
use adaq::io::Json;
use adaq::measure::{calibrate_model, SearchParams};
use adaq::model::{Manifest, ModelArtifacts, WeightStore};
use adaq::nn::softmax;
use adaq::quant::Allocator;
use adaq::rng::{fill_normal, Pcg32};
use adaq::tensor::{matmul, Tensor};

const HIDDEN: usize = 32;
const PIXELS: usize = IMG * IMG;

fn mlp_manifest() -> Manifest {
    let json = format!(
        r#"{{
        "model": "quickstart_mlp", "input_shape": [{IMG},{IMG},1],
        "num_classes": {NUM_CLASSES}, "output": "fc2",
        "num_weighted_layers": 2,
        "total_quantizable_params": {},
        "layers": [
          {{"name":"flat","kind":"flatten","inputs":["input"]}},
          {{"name":"fc1","kind":"dense","inputs":["flat"],"cin":{PIXELS},
           "cout":{HIDDEN},"param_idx_w":1,"param_idx_b":2,"qindex":0,
           "s_i":{}}},
          {{"name":"relu1","kind":"relu","inputs":["fc1"]}},
          {{"name":"fc2","kind":"dense","inputs":["relu1"],"cin":{HIDDEN},
           "cout":{NUM_CLASSES},"param_idx_w":3,"param_idx_b":4,"qindex":1,
           "s_i":{}}}
        ]}}"#,
        PIXELS * HIDDEN + HIDDEN * NUM_CLASSES,
        PIXELS * HIDDEN,
        HIDDEN * NUM_CLASSES,
    );
    Manifest::from_json(&Json::parse(&json).unwrap()).unwrap()
}

/// Train the 2-layer MLP with plain SGD + softmax cross-entropy; the
/// forward *and* backward matmuls run through the blocked GEMM.
fn train_mlp(train: &Dataset, epochs: usize, lr: f32) -> adaq::Result<Vec<Tensor>> {
    let mut rng = Pcg32::new(0x5EED);
    let scaled = |shape: &[usize], scale: f32, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        fill_normal(rng, &mut data);
        for v in data.iter_mut() {
            *v *= scale;
        }
        Tensor::from_vec(shape, data).unwrap()
    };
    let mut w1 = scaled(&[PIXELS, HIDDEN], 1.0 / (PIXELS as f32).sqrt(), &mut rng);
    let mut b1 = Tensor::zeros(&[HIDDEN]);
    let mut w2 = scaled(&[HIDDEN, NUM_CLASSES], 1.0 / (HIDDEN as f32).sqrt(), &mut rng);
    let mut b2 = Tensor::zeros(&[NUM_CLASSES]);

    let batch = 100;
    for epoch in 0..epochs {
        let mut loss_sum = 0f64;
        let mut correct = 0usize;
        for (start, len) in train.batches(batch) {
            let x = train.batch(start, len)?.reshape(&[len, PIXELS])?;
            let y = train.batch_labels(start, len);

            // forward
            let mut h = matmul(&x, &w1)?;
            for row in h.data_mut().chunks_mut(HIDDEN) {
                for (v, &b) in row.iter_mut().zip(b1.data()) {
                    *v = (*v + b).max(0.0);
                }
            }
            let mut z = matmul(&h, &w2)?;
            for row in z.data_mut().chunks_mut(NUM_CLASSES) {
                for (v, &b) in row.iter_mut().zip(b2.data()) {
                    *v += b;
                }
            }
            let p = softmax(&z)?;

            // loss + dz = (p − onehot)/len
            let mut dz = p.clone();
            for (i, &label) in y.iter().enumerate() {
                let row = &mut dz.data_mut()[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
                loss_sum -= (row[label as usize].max(1e-12) as f64).ln();
                row[label as usize] -= 1.0;
                let (pred, _) = Tensor::top2(&p.data()[i * NUM_CLASSES..(i + 1) * NUM_CLASSES]);
                if pred as i32 == label {
                    correct += 1;
                }
            }
            let inv = 1.0 / len as f32;
            for v in dz.data_mut() {
                *v *= inv;
            }

            // backward
            let dw2 = matmul(&h.transpose2()?, &dz)?;
            let mut db2 = vec![0f32; NUM_CLASSES];
            for row in dz.data().chunks(NUM_CLASSES) {
                for (acc, &v) in db2.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            // ReLU mask: h == 0 exactly where the pre-activation was ≤ 0
            let mut dh = matmul(&dz, &w2.transpose2()?)?;
            for (g, &hv) in dh.data_mut().iter_mut().zip(h.data()) {
                if hv == 0.0 {
                    *g = 0.0;
                }
            }
            let dw1 = matmul(&x.transpose2()?, &dh)?;
            let mut db1 = vec![0f32; HIDDEN];
            for row in dh.data().chunks(HIDDEN) {
                for (acc, &v) in db1.iter_mut().zip(row) {
                    *acc += v;
                }
            }

            // SGD step
            for (w, g) in w2.data_mut().iter_mut().zip(dw2.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b2.data_mut().iter_mut().zip(&db2) {
                *w -= lr * g;
            }
            for (w, g) in w1.data_mut().iter_mut().zip(dw1.data()) {
                *w -= lr * g;
            }
            for (w, &g) in b1.data_mut().iter_mut().zip(&db1) {
                *w -= lr * g;
            }
        }
        let n = (train.len() / batch) * batch;
        println!(
            "  epoch {epoch:>2}: loss {:.4}, train acc {:.4}",
            loss_sum / n as f64,
            correct as f64 / n as f64
        );
    }
    Ok(vec![w1, b1, w2, b2])
}

fn main() -> adaq::Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let session = match std::env::args().nth(1) {
        Some(model) => {
            // artifacts mode (needs `make artifacts`)
            Session::open(&root, &model, 250)?
        }
        None => {
            // zero-setup mode: generate data, train in-process, build an
            // in-memory session on the CPU backend
            println!("training quickstart MLP on the procedural shapes dataset…");
            let train = Dataset::generate(3000, TRAIN_SEED);
            let params = train_mlp(&train, 12, 0.3)?;
            let manifest = mlp_manifest();
            let named: Vec<(String, Tensor)> = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
                .iter()
                .map(|s| s.to_string())
                .zip(params)
                .collect();
            let artifacts = ModelArtifacts {
                dir: std::path::PathBuf::from("<in-memory>"),
                manifest,
                weights: WeightStore::from_params(named),
            };
            let test = Dataset::generate(1000, TEST_SEED);
            Session::from_parts(artifacts, test, 250)?
        }
    };

    let model = session.artifacts.manifest.model.clone();
    let base = session.baseline().accuracy;
    println!("{model} [{}]: fp32 accuracy {base:.4}", session.backend_name());

    // calibration (Alg. 1 + 2); Δacc = half the base accuracy, as in the
    // paper's AlexNet example (57% → 28%)
    let cal = calibrate_model(&session, base * 0.5, &SearchParams::default(), |l| {
        println!("{l}")
    })?;

    // closed-form allocation anchored at b1 = 8 bits
    let stats = cal.layer_stats();
    let mask = vec![true; stats.len()];
    let alloc = Allocator::Adaptive.allocate(&stats, 8.0, &mask, 16.0);
    println!("optimal fractional bits: {:?}", alloc.bits);

    // evaluate the quantized model through the session backend
    let bits: Vec<f32> = alloc.bits.iter().map(|&b| b.round() as f32).collect();
    let out = session.eval_qbits(&bits)?;
    let size = alloc.size_bytes(&stats);
    let fp32 = session.artifacts.manifest.fp32_bytes();
    println!(
        "quantized: accuracy {:.4} (drop {:.4}), size {:.1} KiB = {:.2}x smaller than fp32",
        out.accuracy,
        base - out.accuracy,
        size / 1024.0,
        fp32 / size
    );
    Ok(())
}
