//! Quickstart: the complete adaptive-quantization pipeline on one model,
//! in ~60 lines of library calls.
//!
//!   cargo run --release --example quickstart
//!
//! Steps (= the paper's method, end to end):
//!   1. open a PJRT session on the AOT artifacts (`make artifacts` first),
//!   2. calibrate per-layer robustness t_i and noise prefactor p_i,
//!   3. solve the closed-form optimal bit-widths (Eq. 22),
//!   4. evaluate the quantized model through the Pallas fake-quant
//!      executable and report accuracy vs model size.

use adaq::coordinator::Session;
use adaq::measure::{calibrate_model, SearchParams};
use adaq::quant::Allocator;

fn main() -> adaq::Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let model = std::env::args().nth(1).unwrap_or_else(|| "mini_alexnet".into());

    // 1. session: loads HLO artifacts, uploads dataset + weights, caches
    //    the fp32 baseline logits
    let session = Session::open(&root, &model, 250)?;
    let base = session.baseline().accuracy;
    println!("{model}: fp32 accuracy {base:.4}");

    // 2. calibration (Alg. 1 + 2); Δacc = half the base accuracy, as in
    //    the paper's AlexNet example (57% → 28%)
    let cal = calibrate_model(&session, base * 0.5, &SearchParams::default(), |l| {
        println!("{l}")
    })?;

    // 3. closed-form allocation anchored at b1 = 8 bits
    let stats = cal.layer_stats();
    let mask = vec![true; stats.len()];
    let alloc = Allocator::Adaptive.allocate(&stats, 8.0, &mask, 16.0);
    println!("optimal fractional bits: {:?}", alloc.bits);

    // 4. evaluate through the Pallas qforward executable
    let bits: Vec<f32> = alloc.bits.iter().map(|&b| b.round() as f32).collect();
    let out = session.eval_qbits(&bits)?;
    let size = alloc.size_bytes(&stats);
    let fp32 = session.artifacts.manifest.fp32_bytes();
    println!(
        "quantized: accuracy {:.4} (drop {:.4}), size {:.1} KiB = {:.2}x smaller than fp32",
        out.accuracy,
        base - out.accuracy,
        size / 1024.0,
        fp32 / size
    );
    Ok(())
}
